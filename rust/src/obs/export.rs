//! Stable-schema JSON snapshot exporters + validators.
//!
//! The perf trajectory lives in three committed files at the repo root:
//! `BENCH_infer.json` (hot-path latency with per-step attribution, from
//! `benches/infer_hot.rs`), `BENCH_serve.json` (serving load numbers,
//! from `benches/serve_load.rs`), and `BENCH_kernels.json` (per-kernel
//! naive-vs-engineered microbenchmarks with parity tags, from
//! `benches/kernels.rs`). All carry the schema tag
//! [`BENCH_SCHEMA`]; the validators here are what the benches self-check
//! against before writing, and what `msfcnn bench check` /
//! `make bench-snapshot` / CI run afterwards — a snapshot whose shape
//! drifts fails the gate instead of silently rotting the trajectory.
//! `msfcnn verify --json` exports the static verifier's findings the
//! same way under [`ANALYSIS_SCHEMA`].
//!
//! The writers are hand-rolled (no serde in the offline build); the
//! validators parse with [`crate::util::json`] and name the missing or
//! mistyped field on failure.

use crate::util::error::Result;
use crate::util::json::{escape, Json};
use crate::{anyhow, bail};

use super::profile::StepProfile;

/// Schema tag every committed `BENCH_*.json` carries. Bump only with a
/// deliberate, documented format change. v2 added the int8 columns
/// (`quant_*`) to the infer snapshot alongside the quantized executor.
pub const BENCH_SCHEMA: &str = "msfcnn.bench/v2";

/// Schema tag of standalone `msfcnn profile --json` snapshots.
pub const PROFILE_SCHEMA: &str = "msfcnn.profile/v1";

/// Schema tag of `msfcnn verify --json` snapshots: the static
/// verifier's structured [`crate::analysis::AnalysisReport`]s, one row
/// per analyzed plan.
pub const ANALYSIS_SCHEMA: &str = "msfcnn.analysis/v1";

fn jstr(s: &str) -> String {
    format!("\"{}\"", escape(s))
}

fn jnum(v: f64) -> String {
    if v.is_finite() {
        format!("{v:.3}")
    } else {
        "0.0".to_string()
    }
}

/// One model's row in `BENCH_infer.json`.
#[derive(Debug, Clone)]
pub struct InferRow {
    pub model: String,
    /// Interpreted engine (per-run re-walk + arena allocations), µs/run.
    pub interpreted_us: f64,
    /// One compile (schedule replay + offset assignment), µs.
    pub compile_cold_us: f64,
    /// Warm allocation-free compiled run, µs.
    pub compiled_warm_us: f64,
    pub pool_bytes: u64,
    pub watermark_bytes: u64,
    /// Warm allocation-free int8 ([`crate::qexec::QCompiledPlan`]) run, µs.
    pub quant_warm_us: f64,
    /// Int8 pool size in bytes (byte-granular offset assignment).
    pub quant_pool_bytes: u64,
    /// Int8 pool watermark — the analytic Eq. 5/6 peak, measured.
    pub quant_watermark_bytes: u64,
    /// Max-abs logit error of the int8 path vs the f32 compiled path.
    pub quant_max_abs_err: f64,
    /// Per-step attribution of the warm path.
    pub profile: StepProfile,
}

/// Serialize a [`StepProfile`]'s steps as a JSON array (shared by the
/// infer snapshot and `msfcnn profile --json`). Fused steps with a
/// recorded per-unit breakdown carry a `units` array (stage label,
/// per-run mean, in-step share, MACs); stash/single steps omit the key.
pub fn steps_json(profile: &StepProfile, indent: &str) -> String {
    let rows: Vec<String> = profile
        .steps
        .iter()
        .map(|s| {
            let units = if s.units.is_empty() {
                String::new()
            } else {
                let us: Vec<String> = s
                    .units
                    .iter()
                    .map(|u| {
                        format!(
                            "{{\"label\": {}, \"mean_us\": {}, \"share\": {:.5}, \"macs\": {}}}",
                            jstr(&u.label),
                            jnum(u.mean_us),
                            u.share,
                            u.macs,
                        )
                    })
                    .collect();
                format!(", \"units\": [{}]", us.join(", "))
            };
            format!(
                "{indent}{{\"label\": {}, \"kind\": {}, \"layers\": [{}, {}], \"mean_us\": {}, \"p50_us\": {}, \"p95_us\": {}, \"share\": {:.5}, \"macs\": {}, \"bytes\": {}{units}}}",
                jstr(&s.meta.label),
                jstr(s.meta.kind),
                s.meta.layers.0,
                s.meta.layers.1,
                jnum(s.mean_us),
                jnum(s.p50_us),
                jnum(s.p95_us),
                s.share,
                s.macs,
                s.meta.bytes,
            )
        })
        .collect();
    format!("[\n{}\n{}]", rows.join(",\n"), &indent[..indent.len().saturating_sub(2)])
}

/// Render `BENCH_infer.json`: hot-path latency trajectory with per-step
/// attribution, stable schema [`BENCH_SCHEMA`].
pub fn infer_snapshot(rows: &[InferRow]) -> String {
    let body: Vec<String> = rows
        .iter()
        .map(|r| {
            format!(
                "    {{\n      \"model\": {},\n      \"interpreted_us\": {},\n      \"compile_cold_us\": {},\n      \"compiled_warm_us\": {},\n      \"warm_speedup\": {},\n      \"pool_bytes\": {},\n      \"watermark_bytes\": {},\n      \"quant_warm_us\": {},\n      \"quant_speedup\": {},\n      \"quant_pool_bytes\": {},\n      \"quant_watermark_bytes\": {},\n      \"quant_max_abs_err\": {},\n      \"profile_runs\": {},\n      \"total_step_us\": {},\n      \"steps\": {}\n    }}",
                jstr(&r.model),
                jnum(r.interpreted_us),
                jnum(r.compile_cold_us),
                jnum(r.compiled_warm_us),
                jnum(r.interpreted_us / r.compiled_warm_us.max(1e-9)),
                r.pool_bytes,
                r.watermark_bytes,
                jnum(r.quant_warm_us),
                jnum(r.compiled_warm_us / r.quant_warm_us.max(1e-9)),
                r.quant_pool_bytes,
                r.quant_watermark_bytes,
                format!("{:.6}", r.quant_max_abs_err),
                r.profile.runs,
                jnum(r.profile.total_mean_us),
                steps_json(&r.profile, "        "),
            )
        })
        .collect();
    format!(
        "{{\n  \"schema\": {},\n  \"bench\": \"infer_hot\",\n  \"unit\": \"us\",\n  \"results\": [\n{}\n  ]\n}}\n",
        jstr(BENCH_SCHEMA),
        body.join(",\n")
    )
}

/// One model's row in `BENCH_serve.json`.
#[derive(Debug, Clone)]
pub struct ServeRow {
    pub model: String,
    pub completed: usize,
    pub rejections: usize,
    pub shutdown_drops: usize,
    /// Completed requests per second over the model's active window.
    pub throughput_rps: f64,
    pub mean_us: f64,
    pub p50_us: f64,
    pub p95_us: f64,
    pub p99_us: f64,
    pub max_us: f64,
    /// Mean time requests spent queued before an executor popped them.
    pub queue_wait_mean_us: f64,
    /// Mean backend execution time.
    pub exec_mean_us: f64,
    /// High-water mark of the model's queue depth.
    pub queue_peak: usize,
}

impl ServeRow {
    /// Rejected / offered (completed + rejected) fraction.
    pub fn rejection_rate(&self) -> f64 {
        let offered = self.completed + self.rejections;
        if offered == 0 {
            0.0
        } else {
            self.rejections as f64 / offered as f64
        }
    }
}

/// Load-harness configuration recorded in the snapshot (so a committed
/// number is comparable to its predecessor).
#[derive(Debug, Clone)]
pub struct ServeConfig {
    pub threads: usize,
    pub requests: usize,
    pub smoke: bool,
    pub models: Vec<String>,
}

/// Fleet-wide aggregate across every model in the run.
#[derive(Debug, Clone)]
pub struct ServeAggregate {
    pub completed: usize,
    pub rejections: usize,
    pub throughput_rps: f64,
    /// Percentiles from the merged per-model histograms
    /// (bucket-resolution estimates).
    pub p50_us: f64,
    pub p95_us: f64,
    pub p99_us: f64,
}

/// Render `BENCH_serve.json`: serving load numbers, stable schema
/// [`BENCH_SCHEMA`].
pub fn serve_snapshot(cfg: &ServeConfig, rows: &[ServeRow], agg: &ServeAggregate) -> String {
    let models: Vec<String> = cfg.models.iter().map(|m| jstr(m)).collect();
    let body: Vec<String> = rows
        .iter()
        .map(|r| {
            format!(
                "    {{\"model\": {}, \"completed\": {}, \"rejections\": {}, \"shutdown_drops\": {}, \"rejection_rate\": {:.5}, \"throughput_rps\": {}, \"mean_us\": {}, \"p50_us\": {}, \"p95_us\": {}, \"p99_us\": {}, \"max_us\": {}, \"queue_wait_mean_us\": {}, \"exec_mean_us\": {}, \"queue_peak\": {}}}",
                jstr(&r.model),
                r.completed,
                r.rejections,
                r.shutdown_drops,
                r.rejection_rate(),
                jnum(r.throughput_rps),
                jnum(r.mean_us),
                jnum(r.p50_us),
                jnum(r.p95_us),
                jnum(r.p99_us),
                jnum(r.max_us),
                jnum(r.queue_wait_mean_us),
                jnum(r.exec_mean_us),
                r.queue_peak,
            )
        })
        .collect();
    format!(
        "{{\n  \"schema\": {},\n  \"bench\": \"serve_load\",\n  \"unit\": \"us\",\n  \"config\": {{\"threads\": {}, \"requests\": {}, \"smoke\": {}, \"models\": [{}]}},\n  \"results\": [\n{}\n  ],\n  \"aggregate\": {{\"completed\": {}, \"rejections\": {}, \"throughput_rps\": {}, \"p50_us\": {}, \"p95_us\": {}, \"p99_us\": {}}}\n}}\n",
        jstr(BENCH_SCHEMA),
        cfg.threads,
        cfg.requests,
        cfg.smoke,
        models.join(", "),
        body.join(",\n"),
        agg.completed,
        agg.rejections,
        jnum(agg.throughput_rps),
        jnum(agg.p50_us),
        jnum(agg.p95_us),
        jnum(agg.p99_us),
    )
}

/// One kernel's row in `BENCH_kernels.json`: the engineered hot kernel
/// timed against its retained naive twin in
/// [`crate::ops::reference`], plus the parity contract the bench
/// asserted before timing.
#[derive(Debug, Clone)]
pub struct KernelRow {
    /// Kernel name, e.g. `"conv2d"`, `"qdwconv2d"`.
    pub kernel: String,
    /// `"f32"` or `"int8"`.
    pub dtype: String,
    /// Human-readable problem size, e.g. `"32x32x8 k3 s1 p1 co16"`.
    pub shape: String,
    /// Naive reference kernel, µs/call.
    pub naive_us: f64,
    /// Engineered interior/halo kernel, µs/call.
    pub opt_us: f64,
    /// MACs per call (0 for pools/copies).
    pub macs: u64,
    /// Parity contract asserted before timing: `"bit-identical"` (f32)
    /// or `"exact"` (int8).
    pub parity: String,
}

/// Render `BENCH_kernels.json`: per-kernel naive-vs-engineered
/// microbenchmark trajectory, stable schema [`BENCH_SCHEMA`].
pub fn kernels_snapshot(rows: &[KernelRow], smoke: bool) -> String {
    let body: Vec<String> = rows
        .iter()
        .map(|r| {
            format!(
                "    {{\"kernel\": {}, \"dtype\": {}, \"shape\": {}, \"naive_us\": {}, \"opt_us\": {}, \"speedup\": {}, \"macs\": {}, \"parity\": {}}}",
                jstr(&r.kernel),
                jstr(&r.dtype),
                jstr(&r.shape),
                jnum(r.naive_us),
                jnum(r.opt_us),
                jnum(r.naive_us / r.opt_us.max(1e-9)),
                r.macs,
                jstr(&r.parity),
            )
        })
        .collect();
    format!(
        "{{\n  \"schema\": {},\n  \"bench\": \"kernels\",\n  \"unit\": \"us\",\n  \"smoke\": {},\n  \"results\": [\n{}\n  ]\n}}\n",
        jstr(BENCH_SCHEMA),
        smoke,
        body.join(",\n")
    )
}

/// Render a standalone per-step profile snapshot
/// (`msfcnn profile --json`), schema [`PROFILE_SCHEMA`].
pub fn profile_snapshot(profile: &StepProfile) -> String {
    format!(
        "{{\n  \"schema\": {},\n  \"model\": {},\n  \"setting\": {},\n  \"runs\": {},\n  \"total_step_us\": {},\n  \"steps\": {}\n}}\n",
        jstr(PROFILE_SCHEMA),
        jstr(&profile.model),
        jstr(&profile.setting),
        profile.runs,
        jnum(profile.total_mean_us),
        steps_json(profile, "    "),
    )
}

/// Render a `msfcnn verify --json` snapshot, schema [`ANALYSIS_SCHEMA`]:
/// one row per analyzed plan (`(display name, report)` pairs) carrying
/// severity-split counts, coverage counters, and every structured
/// finding. `step` and `bytes` are `null` when the finding is not
/// step- or range-local.
pub fn analysis_snapshot(rows: &[(String, crate::analysis::AnalysisReport)]) -> String {
    let body: Vec<String> = rows
        .iter()
        .map(|(plan, r)| {
            let findings: Vec<String> = r
                .findings
                .iter()
                .map(|f| {
                    let step = f.step.map_or("null".to_string(), |s| s.to_string());
                    let bytes = f
                        .bytes
                        .map_or("null".to_string(), |(lo, hi)| format!("[{lo}, {hi}]"));
                    format!(
                        "        {{\"class\": {}, \"severity\": {}, \"step\": {step}, \"buffer\": {}, \"bytes\": {bytes}, \"detail\": {}}}",
                        jstr(f.class.name()),
                        jstr(f.severity.name()),
                        jstr(&f.buffer),
                        jstr(&f.detail),
                    )
                })
                .collect();
            let findings_json = if findings.is_empty() {
                "[]".to_string()
            } else {
                format!("[\n{}\n      ]", findings.join(",\n"))
            };
            format!(
                "    {{\n      \"plan\": {},\n      \"errors\": {},\n      \"warnings\": {},\n      \"steps_checked\": {},\n      \"buffers_checked\": {},\n      \"findings\": {}\n    }}",
                jstr(plan),
                r.error_count(),
                r.warn_count(),
                r.steps_checked,
                r.buffers_checked,
                findings_json,
            )
        })
        .collect();
    let errors: usize = rows.iter().map(|(_, r)| r.error_count()).sum();
    let warnings: usize = rows.iter().map(|(_, r)| r.warn_count()).sum();
    format!(
        "{{\n  \"schema\": {},\n  \"plans\": {},\n  \"errors\": {},\n  \"warnings\": {},\n  \"results\": [\n{}\n  ]\n}}\n",
        jstr(ANALYSIS_SCHEMA),
        rows.len(),
        errors,
        warnings,
        body.join(",\n")
    )
}

// ---------------------------------------------------------------------
// Validators
// ---------------------------------------------------------------------

fn need<'a>(v: &'a Json, key: &str, at: &str) -> Result<&'a Json> {
    v.get(key).ok_or_else(|| anyhow!("snapshot schema: missing '{at}.{key}'"))
}

fn need_num(v: &Json, key: &str, at: &str) -> Result<f64> {
    need(v, key, at)?
        .as_f64()
        .ok_or_else(|| anyhow!("snapshot schema: '{at}.{key}' is not a number"))
}

fn need_str<'a>(v: &'a Json, key: &str, at: &str) -> Result<&'a str> {
    need(v, key, at)?
        .as_str()
        .ok_or_else(|| anyhow!("snapshot schema: '{at}.{key}' is not a string"))
}

fn need_arr<'a>(v: &'a Json, key: &str, at: &str) -> Result<&'a [Json]> {
    need(v, key, at)?
        .as_arr()
        .ok_or_else(|| anyhow!("snapshot schema: '{at}.{key}' is not an array"))
}

fn check_header(root: &Json, bench: &str) -> Result<()> {
    let schema = need_str(root, "schema", "$")?;
    if schema != BENCH_SCHEMA {
        bail!("snapshot schema: expected '{BENCH_SCHEMA}', found '{schema}'");
    }
    let b = need_str(root, "bench", "$")?;
    if b != bench {
        bail!("snapshot schema: expected bench '{bench}', found '{b}'");
    }
    need_str(root, "unit", "$")?;
    Ok(())
}

fn check_steps(row: &Json, at: &str) -> Result<()> {
    let steps = need_arr(row, "steps", at)?;
    if steps.is_empty() {
        bail!("snapshot schema: '{at}.steps' is empty");
    }
    for (i, s) in steps.iter().enumerate() {
        let sat = format!("{at}.steps[{i}]");
        need_str(s, "label", &sat)?;
        need_str(s, "kind", &sat)?;
        let layers = need_arr(s, "layers", &sat)?;
        if layers.len() != 2 {
            bail!("snapshot schema: '{sat}.layers' must have 2 entries");
        }
        for key in ["mean_us", "p50_us", "p95_us", "share", "macs", "bytes"] {
            need_num(s, key, &sat)?;
        }
        // Per-unit breakdown is optional (stash/single steps have none),
        // but when present every entry must be fully formed.
        if let Some(units) = s.get("units") {
            let units = units
                .as_arr()
                .ok_or_else(|| anyhow!("snapshot schema: '{sat}.units' is not an array"))?;
            for (j, u) in units.iter().enumerate() {
                let uat = format!("{sat}.units[{j}]");
                need_str(u, "label", &uat)?;
                for key in ["mean_us", "share", "macs"] {
                    need_num(u, key, &uat)?;
                }
            }
        }
    }
    Ok(())
}

/// Validate a `BENCH_infer.json` document against the stable schema.
pub fn validate_infer_snapshot(text: &str) -> Result<()> {
    let root = Json::parse(text).map_err(|e| anyhow!("BENCH_infer.json: {e}"))?;
    check_header(&root, "infer_hot")?;
    let results = need_arr(&root, "results", "$")?;
    if results.is_empty() {
        bail!("snapshot schema: '$.results' is empty");
    }
    for (i, row) in results.iter().enumerate() {
        let at = format!("$.results[{i}]");
        need_str(row, "model", &at)?;
        for key in [
            "interpreted_us",
            "compile_cold_us",
            "compiled_warm_us",
            "warm_speedup",
            "pool_bytes",
            "watermark_bytes",
            "quant_warm_us",
            "quant_speedup",
            "quant_pool_bytes",
            "quant_watermark_bytes",
            "quant_max_abs_err",
            "profile_runs",
            "total_step_us",
        ] {
            need_num(row, key, &at)?;
        }
        check_steps(row, &at)?;
    }
    Ok(())
}

/// Validate a `BENCH_serve.json` document against the stable schema.
pub fn validate_serve_snapshot(text: &str) -> Result<()> {
    let root = Json::parse(text).map_err(|e| anyhow!("BENCH_serve.json: {e}"))?;
    check_header(&root, "serve_load")?;
    let cfg = need(&root, "config", "$")?;
    for key in ["threads", "requests"] {
        need_num(cfg, key, "$.config")?;
    }
    need(cfg, "smoke", "$.config")?;
    need_arr(cfg, "models", "$.config")?;
    let results = need_arr(&root, "results", "$")?;
    if results.is_empty() {
        bail!("snapshot schema: '$.results' is empty");
    }
    for (i, row) in results.iter().enumerate() {
        let at = format!("$.results[{i}]");
        need_str(row, "model", &at)?;
        for key in [
            "completed",
            "rejections",
            "shutdown_drops",
            "rejection_rate",
            "throughput_rps",
            "mean_us",
            "p50_us",
            "p95_us",
            "p99_us",
            "max_us",
            "queue_wait_mean_us",
            "exec_mean_us",
            "queue_peak",
        ] {
            need_num(row, key, &at)?;
        }
    }
    let agg = need(&root, "aggregate", "$")?;
    for key in ["completed", "rejections", "throughput_rps", "p50_us", "p95_us", "p99_us"] {
        need_num(agg, key, "$.aggregate")?;
    }
    Ok(())
}

/// Validate a `BENCH_kernels.json` document against the stable schema.
pub fn validate_kernels_snapshot(text: &str) -> Result<()> {
    let root = Json::parse(text).map_err(|e| anyhow!("BENCH_kernels.json: {e}"))?;
    check_header(&root, "kernels")?;
    need(&root, "smoke", "$")?;
    let results = need_arr(&root, "results", "$")?;
    if results.is_empty() {
        bail!("snapshot schema: '$.results' is empty");
    }
    for (i, row) in results.iter().enumerate() {
        let at = format!("$.results[{i}]");
        for key in ["kernel", "dtype", "shape", "parity"] {
            need_str(row, key, &at)?;
        }
        for key in ["naive_us", "opt_us", "speedup", "macs"] {
            need_num(row, key, &at)?;
        }
        let parity = need_str(row, "parity", &at)?;
        if parity != "bit-identical" && parity != "exact" {
            bail!(
                "snapshot schema: '{at}.parity' must be 'bit-identical' or 'exact', found '{parity}'"
            );
        }
    }
    Ok(())
}

/// Validate a `msfcnn profile --json` document.
pub fn validate_profile_snapshot(text: &str) -> Result<()> {
    let root = Json::parse(text).map_err(|e| anyhow!("profile snapshot: {e}"))?;
    let schema = need_str(&root, "schema", "$")?;
    if schema != PROFILE_SCHEMA {
        bail!("snapshot schema: expected '{PROFILE_SCHEMA}', found '{schema}'");
    }
    need_str(&root, "model", "$")?;
    need_str(&root, "setting", "$")?;
    need_num(&root, "runs", "$")?;
    need_num(&root, "total_step_us", "$")?;
    check_steps(&root, "$")
}

/// Validate a `msfcnn verify --json` document against [`ANALYSIS_SCHEMA`].
pub fn validate_analysis_snapshot(text: &str) -> Result<()> {
    let root = Json::parse(text).map_err(|e| anyhow!("analysis snapshot: {e}"))?;
    let schema = need_str(&root, "schema", "$")?;
    if schema != ANALYSIS_SCHEMA {
        bail!("snapshot schema: expected '{ANALYSIS_SCHEMA}', found '{schema}'");
    }
    for key in ["plans", "errors", "warnings"] {
        need_num(&root, key, "$")?;
    }
    let results = need_arr(&root, "results", "$")?;
    if results.is_empty() {
        bail!("snapshot schema: '$.results' is empty");
    }
    if results.len() as f64 != need_num(&root, "plans", "$")? {
        bail!("snapshot schema: '$.plans' disagrees with '$.results' length");
    }
    for (i, row) in results.iter().enumerate() {
        let at = format!("$.results[{i}]");
        need_str(row, "plan", &at)?;
        for key in ["errors", "warnings", "steps_checked", "buffers_checked"] {
            need_num(row, key, &at)?;
        }
        let findings = need_arr(row, "findings", &at)?;
        for (j, f) in findings.iter().enumerate() {
            let fat = format!("{at}.findings[{j}]");
            let class = need_str(f, "class", &fat)?;
            if crate::analysis::DefectClass::from_name(class).is_none() {
                bail!("snapshot schema: '{fat}.class' is not a known defect class: '{class}'");
            }
            let sev = need_str(f, "severity", &fat)?;
            if sev != "error" && sev != "warn" {
                bail!("snapshot schema: '{fat}.severity' must be 'error' or 'warn', found '{sev}'");
            }
            need_str(f, "buffer", &fat)?;
            need_str(f, "detail", &fat)?;
            // Optional locations are still required keys: null or value.
            need(f, "step", &fat)?;
            need(f, "bytes", &fat)?;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::CompiledPlan;
    use crate::obs::profile_plan;
    use crate::ops::{ParamGen, Tensor};
    use crate::optimizer::Planner;
    use crate::zoo;

    fn tiny_profile() -> StepProfile {
        let m = zoo::tiny_cnn();
        let setting = Planner::for_model(m.clone()).setting().unwrap();
        let compiled = CompiledPlan::compile(m, setting);
        let s = compiled.model().shapes[0];
        let x = Tensor::from_data(
            s.h as usize,
            s.w as usize,
            s.c as usize,
            ParamGen::new(1).fill(s.elems() as usize, 2.0),
        );
        profile_plan(&compiled, &x, 3)
    }

    #[test]
    fn infer_snapshot_roundtrips_through_its_validator() {
        let p = tiny_profile();
        let rows = vec![InferRow {
            model: "tiny".into(),
            interpreted_us: 100.0,
            compile_cold_us: 50.0,
            compiled_warm_us: 20.0,
            pool_bytes: 4096,
            watermark_bytes: 4000,
            quant_warm_us: 12.0,
            quant_pool_bytes: 1100,
            quant_watermark_bytes: 1000,
            quant_max_abs_err: 0.03,
            profile: p,
        }];
        let json = infer_snapshot(&rows);
        validate_infer_snapshot(&json).unwrap();
    }

    #[test]
    fn serve_snapshot_roundtrips_through_its_validator() {
        let cfg = ServeConfig {
            threads: 4,
            requests: 100,
            smoke: true,
            models: vec!["tiny".into(), "kws".into()],
        };
        let rows = vec![ServeRow {
            model: "tiny".into(),
            completed: 90,
            rejections: 10,
            shutdown_drops: 0,
            throughput_rps: 1234.5,
            mean_us: 80.0,
            p50_us: 75.0,
            p95_us: 120.0,
            p99_us: 150.0,
            max_us: 200.0,
            queue_wait_mean_us: 30.0,
            exec_mean_us: 50.0,
            queue_peak: 7,
        }];
        let agg = ServeAggregate {
            completed: 90,
            rejections: 10,
            throughput_rps: 1234.5,
            p50_us: 75.0,
            p95_us: 120.0,
            p99_us: 150.0,
        };
        let json = serve_snapshot(&cfg, &rows, &agg);
        validate_serve_snapshot(&json).unwrap();
        assert!((rows[0].rejection_rate() - 0.1).abs() < 1e-12);
    }

    #[test]
    fn profile_snapshot_roundtrips_through_its_validator() {
        let json = profile_snapshot(&tiny_profile());
        validate_profile_snapshot(&json).unwrap();
    }

    #[test]
    fn steps_json_carries_per_unit_breakdown() {
        let p = tiny_profile();
        assert!(
            p.steps.iter().any(|s| !s.units.is_empty()),
            "tiny plan recorded no fused units"
        );
        let json = profile_snapshot(&p);
        assert!(json.contains("\"units\": ["), "{json}");
        // A mistyped unit entry is schema drift.
        let broken = json.replace("\"units\": [{\"label\"", "\"units\": [{\"renamed\"");
        assert!(validate_profile_snapshot(&broken).is_err());
    }

    #[test]
    fn kernels_snapshot_roundtrips_and_rejects_drift() {
        let rows = vec![
            KernelRow {
                kernel: "conv2d".into(),
                dtype: "f32".into(),
                shape: "32x32x8 k3 s1 p1 co16".into(),
                naive_us: 120.0,
                opt_us: 60.0,
                macs: 1_179_648,
                parity: "bit-identical".into(),
            },
            KernelRow {
                kernel: "qconv2d".into(),
                dtype: "int8".into(),
                shape: "32x32x8 k3 s1 p1 co16".into(),
                naive_us: 90.0,
                opt_us: 30.0,
                macs: 1_179_648,
                parity: "exact".into(),
            },
        ];
        let json = kernels_snapshot(&rows, false);
        validate_kernels_snapshot(&json).unwrap();
        assert!(json.contains("\"speedup\": 2.000"), "{json}");
        // A renamed field is schema drift.
        let broken = json.replace("\"opt_us\"", "\"renamed_field\"");
        let err = validate_kernels_snapshot(&broken).unwrap_err();
        assert!(err.to_string().contains("opt_us"), "{err}");
        // An unknown parity contract is drift.
        let bad_parity = json.replace("\"bit-identical\"", "\"approximate\"");
        assert!(validate_kernels_snapshot(&bad_parity).is_err());
        // The infer validator must not accept a kernels doc.
        assert!(validate_infer_snapshot(&json).is_err());
        // Empty results are drift too.
        let empty = format!(
            "{{\"schema\": \"{BENCH_SCHEMA}\", \"bench\": \"kernels\", \"unit\": \"us\", \"smoke\": false, \"results\": []}}"
        );
        assert!(validate_kernels_snapshot(&empty).is_err());
    }

    #[test]
    fn analysis_snapshot_roundtrips_through_its_validator() {
        use crate::analysis::{AnalysisReport, DefectClass, Finding};
        let mut clean = AnalysisReport::new();
        clean.steps_checked = 4;
        clean.buffers_checked = 6;
        let mut dirty = AnalysisReport::new();
        dirty.steps_checked = 2;
        dirty.buffers_checked = 3;
        dirty.push(
            Finding::new(DefectClass::AccumulatorOverflow, "bound exceeds i32")
                .at_step(1)
                .on_buffer("v1"),
        );
        dirty.push(
            Finding::new(DefectClass::DeadStore, "store is never read")
                .warn()
                .at_step(0)
                .on_buffer("buf0")
                .in_bytes(0, 63),
        );
        let rows = vec![("clean.json".to_string(), clean), ("dirty.json".to_string(), dirty)];
        let json = analysis_snapshot(&rows);
        validate_analysis_snapshot(&json).unwrap();
        assert!(json.contains("\"errors\": 1"), "{json}");
        assert!(json.contains("\"warnings\": 1"), "{json}");
        assert!(json.contains("\"severity\": \"warn\""), "{json}");
        assert!(json.contains("\"bytes\": [0, 63]"), "{json}");
    }

    #[test]
    fn analysis_validator_rejects_drift() {
        use crate::analysis::{AnalysisReport, DefectClass, Finding};
        let mut report = AnalysisReport::new();
        report.steps_checked = 1;
        report.buffers_checked = 1;
        report.push(Finding::new(DefectClass::DeadStore, "x").warn().at_step(0));
        let json = analysis_snapshot(&[("p.json".to_string(), report)]);
        // A renamed field is schema drift.
        let broken = json.replace("\"steps_checked\"", "\"renamed_field\"");
        let err = validate_analysis_snapshot(&broken).unwrap_err();
        assert!(err.to_string().contains("steps_checked"), "{err}");
        // A defect class the binary does not know is drift.
        let unknown = json.replace("\"dead-store\"", "\"made-up-class\"");
        assert!(validate_analysis_snapshot(&unknown).is_err());
        // A schema version bump fails the v1 gate.
        let v2 = json.replace("msfcnn.analysis/v1", "msfcnn.analysis/v2");
        assert!(validate_analysis_snapshot(&v2).is_err());
        // Empty results are drift too.
        let empty = format!(
            "{{\"schema\": \"{ANALYSIS_SCHEMA}\", \"plans\": 0, \"errors\": 0, \"warnings\": 0, \"results\": []}}"
        );
        assert!(validate_analysis_snapshot(&empty).is_err());
    }

    #[test]
    fn validators_reject_drift() {
        // Wrong bench tag.
        let p = tiny_profile();
        let infer = infer_snapshot(&[InferRow {
            model: "tiny".into(),
            interpreted_us: 1.0,
            compile_cold_us: 1.0,
            compiled_warm_us: 1.0,
            pool_bytes: 1,
            watermark_bytes: 1,
            quant_warm_us: 1.0,
            quant_pool_bytes: 1,
            quant_watermark_bytes: 1,
            quant_max_abs_err: 0.0,
            profile: p,
        }]);
        assert!(validate_serve_snapshot(&infer).is_err(), "serve validator took infer doc");
        // A removed field is schema drift.
        let broken = infer.replace("\"compiled_warm_us\"", "\"renamed_field\"");
        let err = validate_infer_snapshot(&broken).unwrap_err();
        assert!(err.to_string().contains("compiled_warm_us"), "{err}");
        // Missing int8 columns are drift.
        let no_quant = infer.replace("\"quant_warm_us\"", "\"legacy_field\"");
        let err = validate_infer_snapshot(&no_quant).unwrap_err();
        assert!(err.to_string().contains("quant_warm_us"), "{err}");
        // Pre-quantization v1 snapshots fail the v2 gate.
        let v1 = infer.replace("msfcnn.bench/v2", "msfcnn.bench/v1");
        assert!(validate_infer_snapshot(&v1).is_err(), "v1 snapshot passed the v2 gate");
        // Empty results are drift too.
        let empty = format!(
            "{{\"schema\": \"{BENCH_SCHEMA}\", \"bench\": \"infer_hot\", \"unit\": \"us\", \"results\": []}}"
        );
        assert!(validate_infer_snapshot(&empty).is_err());
    }
}
