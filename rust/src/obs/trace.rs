//! Structured control-plane trace events with a pluggable sink.
//!
//! The serving control plane ([`crate::coordinator::ServerHandle`])
//! emits a [`TraceEvent`] for every lifecycle transition — deploy, swap,
//! retire, executor drain, shutdown — and
//! [`crate::coordinator::PlanRegistry::sync`] emits the registry deltas
//! it applied. Events flow into whatever [`TraceSink`] the server was
//! given: the default sink discards them (zero overhead beyond an
//! `Arc` deref per event), [`TraceLog`] buffers them for tests and
//! post-mortems, [`StderrSink`] prints them live (`msfcnn serve
//! --trace`).

use std::sync::{Arc, Mutex};

/// One control-plane lifecycle event.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum TraceEvent {
    /// A model entered the live registry.
    Deploy { model_id: String },
    /// A live model was hot-swapped (old backend drains, new one serves).
    Swap { model_id: String },
    /// A model left the live registry (its queue drains to completion).
    Retire { model_id: String },
    /// A model's executor exited after draining its queue; `drained` is
    /// the number of queued requests answered with a structured
    /// `ShuttingDown` reply instead of executing.
    Drain { model_id: String, drained: usize },
    /// The whole server stopped accepting requests.
    Shutdown,
    /// One `PlanRegistry::sync` pass applied these deltas to the server.
    RegistrySync {
        added: Vec<String>,
        updated: Vec<String>,
        removed: Vec<String>,
        /// Files that failed to load/validate this scan.
        errors: usize,
        /// Model ids claimed by more than one plan file this scan.
        conflicts: usize,
    },
}

impl TraceEvent {
    /// The model this event concerns (`None` for server-wide events).
    pub fn model_id(&self) -> Option<&str> {
        match self {
            TraceEvent::Deploy { model_id }
            | TraceEvent::Swap { model_id }
            | TraceEvent::Retire { model_id }
            | TraceEvent::Drain { model_id, .. } => Some(model_id),
            TraceEvent::Shutdown | TraceEvent::RegistrySync { .. } => None,
        }
    }
}

impl std::fmt::Display for TraceEvent {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TraceEvent::Deploy { model_id } => write!(f, "deploy '{model_id}'"),
            TraceEvent::Swap { model_id } => write!(f, "swap '{model_id}'"),
            TraceEvent::Retire { model_id } => write!(f, "retire '{model_id}'"),
            TraceEvent::Drain { model_id, drained } => {
                write!(f, "drain '{model_id}' ({drained} queued request(s) shed)")
            }
            TraceEvent::Shutdown => write!(f, "shutdown"),
            TraceEvent::RegistrySync { added, updated, removed, errors, conflicts } => write!(
                f,
                "registry sync: +{added:?} ~{updated:?} -{removed:?} ({errors} error(s), {conflicts} conflict(s))"
            ),
        }
    }
}

/// Where trace events go. Sinks must be `Send`: the server's executor
/// threads emit drain events from their own threads.
pub trait TraceSink: Send {
    fn emit(&mut self, event: TraceEvent);
}

/// A sink shareable across the control plane and its executor threads.
pub type SharedSink = Arc<Mutex<Box<dyn TraceSink>>>;

/// The default sink: events are dropped.
#[derive(Debug, Default, Clone, Copy)]
pub struct NullSink;

impl TraceSink for NullSink {
    fn emit(&mut self, _event: TraceEvent) {}
}

/// Prints every event to stderr — the live view `msfcnn serve --trace`
/// wires up.
#[derive(Debug, Default, Clone, Copy)]
pub struct StderrSink;

impl TraceSink for StderrSink {
    fn emit(&mut self, event: TraceEvent) {
        eprintln!("TRACE: {event}");
    }
}

/// In-memory event buffer. Cloning shares the buffer, so a test (or a
/// post-mortem reader) keeps a handle while the server owns the sink.
#[derive(Debug, Default, Clone)]
pub struct TraceLog {
    events: Arc<Mutex<Vec<TraceEvent>>>,
}

impl TraceLog {
    pub fn new() -> Self {
        Self::default()
    }

    /// Snapshot of every event emitted so far, in emission order.
    pub fn events(&self) -> Vec<TraceEvent> {
        self.events.lock().unwrap().clone()
    }

    /// Number of events emitted so far.
    pub fn len(&self) -> usize {
        self.events.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl TraceSink for TraceLog {
    fn emit(&mut self, event: TraceEvent) {
        self.events.lock().unwrap().push(event);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn log_clone_shares_the_buffer() {
        let log = TraceLog::new();
        let mut sink = log.clone();
        sink.emit(TraceEvent::Deploy { model_id: "a".into() });
        sink.emit(TraceEvent::Shutdown);
        assert_eq!(log.len(), 2);
        assert_eq!(log.events()[0].model_id(), Some("a"));
        assert_eq!(log.events()[1], TraceEvent::Shutdown);
    }

    #[test]
    fn events_render_for_logs() {
        let e = TraceEvent::Drain { model_id: "kws".into(), drained: 3 };
        assert!(e.to_string().contains("drain 'kws'"), "{e}");
        let s = TraceEvent::RegistrySync {
            added: vec!["a".into()],
            updated: vec![],
            removed: vec![],
            errors: 1,
            conflicts: 2,
        };
        assert!(s.to_string().contains("2 conflict(s)"), "{s}");
    }
}
