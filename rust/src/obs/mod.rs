//! `obs` — the end-to-end observability layer.
//!
//! Measurement has to exist before optimization can be honest: every
//! claimed speedup should land as a before/after delta in a committed
//! `BENCH_*.json` snapshot, and kernel work needs to know *which*
//! compiled steps dominate. This module provides the four pieces that
//! make that possible, shared by the execution layer, the serving
//! coordinator, the benches, and the CLI:
//!
//! * [`profile`] — per-step profiling of [`crate::exec::CompiledPlan`]
//!   runs: a zero-cost-when-disabled [`StepProfiler`] trait
//!   (monomorphized; [`NoProfiler`] compiles to the exact unprofiled hot
//!   path), a wall-clock [`StepRecorder`], and [`StepProfile`]
//!   aggregation across runs into per-step mean/p50/p95, time shares,
//!   and a top-k dominating-steps view.
//! * [`hist`] — fixed-bucket, mergeable [`LatencyHistogram`]s (log-spaced
//!   bounds), so serving percentiles can be combined across models and
//!   processes without retaining raw samples, plus the ceil-based
//!   [`nearest_rank`] percentile every exact window shares.
//! * [`trace`] — structured control-plane lifecycle events
//!   ([`TraceEvent`]: deploy/swap/retire/drain/shutdown + registry sync
//!   deltas) behind a pluggable [`TraceSink`] ([`TraceLog`] buffers in
//!   memory, [`StderrSink`] prints).
//! * [`export`] — JSON snapshot exporters with a **stable schema**
//!   (`msfcnn.bench/v1`) for `BENCH_infer.json` / `BENCH_serve.json` and
//!   the matching validators `make bench-snapshot` and CI gate on.

pub mod export;
pub mod hist;
pub mod profile;
pub mod trace;

pub use hist::{nearest_rank, LatencyHistogram};
pub use profile::{
    profile_plan, NoProfiler, StepMeta, StepProfile, StepProfiler, StepRecorder, StepStat,
    UnitStat,
};
pub use trace::{NullSink, SharedSink, StderrSink, TraceEvent, TraceLog, TraceSink};
