//! Per-step profiling of compiled execution plans.
//!
//! [`StepProfiler`] is the instrumentation seam inside
//! [`CompiledPlan::run_profiled`](crate::exec::CompiledPlan::run_profiled):
//! the executor calls `begin(step)` / `end(step, macs)` around every
//! compiled step. The trait is **monomorphized** — with [`NoProfiler`]
//! both calls are empty `#[inline(always)]` bodies, so the unprofiled
//! hot path compiles to exactly the allocation-free `run_into` loop
//! (the parity test in `rust/tests/obs_profile.rs` pins bit-identical
//! logits/MACs and an unchanged [`PlanPool`](crate::exec::PlanPool)
//! allocation counter).
//!
//! [`StepRecorder`] is the measuring implementation: wall time per step
//! per run, aggregated by [`StepProfile::from_recorder`] into per-step
//! mean/p50/p95, time shares, and a top-k dominating-steps view — the
//! per-step attribution `msfcnn profile`, `benches/infer_hot.rs`, and
//! `report::table_steps` print.

use std::time::Instant;

use crate::exec::CompiledPlan;
use crate::ops::{Tensor, UnitProfiler};

use super::hist::nearest_rank;

/// Instrumentation hooks around every compiled step. Implementations
/// must be cheap: `begin`/`end` run inside the serving hot path when
/// profiling is on, and must compile to nothing when it is off
/// ([`NoProfiler`]).
///
/// [`UnitProfiler`] is a supertrait: a step profiler also observes the
/// per-unit brackets *inside* fused steps (block layers, the copy-out
/// sink, iterative-tail stages), so fused spans are attributable
/// per layer instead of appearing as one opaque step.
pub trait StepProfiler: UnitProfiler {
    /// Called immediately before step `idx` executes.
    fn begin(&mut self, idx: usize);
    /// Called immediately after step `idx`, with the MACs it performed.
    fn end(&mut self, idx: usize, macs: u64);
}

/// The disabled profiler: all hooks are empty and `#[inline(always)]`,
/// so `run_profiled::<NoProfiler>` monomorphizes to the exact unprofiled
/// step loop — zero cost, bit-identical numerics, no allocations.
#[derive(Debug, Default, Clone, Copy)]
pub struct NoProfiler;

impl UnitProfiler for NoProfiler {
    #[inline(always)]
    fn unit_begin(&mut self) {}
    #[inline(always)]
    fn unit_end(&mut self, _unit: usize, _macs: u64) {}
}

impl StepProfiler for NoProfiler {
    #[inline(always)]
    fn begin(&mut self, _idx: usize) {}
    #[inline(always)]
    fn end(&mut self, _idx: usize, _macs: u64) {}
}

/// Wall-clock recorder: per-step latency samples across runs, plus the
/// per-step MAC count (identical every run — the plan is static).
/// Allocates its sample storage up front; recording itself only pushes
/// into pre-created vectors (per-unit rows grow lazily on the first
/// profiled run, then stay put).
#[derive(Debug, Clone)]
pub struct StepRecorder {
    started: Option<Instant>,
    samples_us: Vec<Vec<f64>>,
    macs: Vec<u64>,
    /// Step currently between `begin` and `end` — routes unit brackets.
    cur_step: usize,
    unit_started: Option<Instant>,
    /// Per step, per unit: total µs across all rows and runs.
    unit_us: Vec<Vec<f64>>,
    /// Per step, per unit: total MACs across all rows and runs.
    unit_macs: Vec<Vec<u64>>,
}

impl StepRecorder {
    /// Recorder for a plan with `num_steps` compiled steps.
    pub fn new(num_steps: usize) -> Self {
        Self {
            started: None,
            samples_us: vec![Vec::new(); num_steps],
            macs: vec![0; num_steps],
            cur_step: 0,
            unit_started: None,
            unit_us: vec![Vec::new(); num_steps],
            unit_macs: vec![Vec::new(); num_steps],
        }
    }

    /// Completed runs recorded so far.
    pub fn runs(&self) -> usize {
        self.samples_us.first().map_or(0, Vec::len)
    }

    /// Latency samples (µs) of step `idx`, one per run.
    pub fn samples_us(&self, idx: usize) -> &[f64] {
        &self.samples_us[idx]
    }

    /// MACs step `idx` performed per run.
    pub fn macs(&self, idx: usize) -> u64 {
        self.macs[idx]
    }
}

impl UnitProfiler for StepRecorder {
    fn unit_begin(&mut self) {
        self.unit_started = Some(Instant::now());
    }

    fn unit_end(&mut self, unit: usize, macs: u64) {
        let t0 = self.unit_started.take().expect("unit_end without unit_begin");
        let us = t0.elapsed().as_secs_f64() * 1e6;
        let step_us = &mut self.unit_us[self.cur_step];
        if step_us.len() <= unit {
            step_us.resize(unit + 1, 0.0);
        }
        step_us[unit] += us;
        let step_macs = &mut self.unit_macs[self.cur_step];
        if step_macs.len() <= unit {
            step_macs.resize(unit + 1, 0);
        }
        step_macs[unit] += macs;
    }
}

impl StepProfiler for StepRecorder {
    fn begin(&mut self, idx: usize) {
        self.cur_step = idx;
        self.started = Some(Instant::now());
    }

    fn end(&mut self, idx: usize, macs: u64) {
        let t0 = self.started.take().expect("StepProfiler::end without begin");
        self.samples_us[idx].push(t0.elapsed().as_secs_f64() * 1e6);
        self.macs[idx] = macs;
    }
}

/// Static description of one compiled step, derived from the plan at
/// compile time (independent of any run).
#[derive(Debug, Clone)]
pub struct StepMeta {
    /// Position in the compiled step list.
    pub index: usize,
    /// Step kind tag: `"stash"`, `"single"`, `"fused"`, `"fused-iter"`.
    pub kind: &'static str,
    /// Human-readable label, e.g. `"conv2d[3]"` or `"fused[0..4)"`.
    pub label: String,
    /// Model-layer range `[start, end)` the step executes (stash steps
    /// report the boundary tensor index as an empty range).
    pub layers: (usize, usize),
    /// Bytes the step touches per run: pool slices read + written plus
    /// the parameters it streams (f32 storage convention).
    pub bytes: u64,
}

/// Aggregated timing of one **unit** — a sub-step stage inside a fused
/// span (block layer, copy-out sink, gap / dense / logits tail stage).
/// Unit times are measured by the [`UnitProfiler`] brackets and summed
/// across all streamed rows of a run, so `mean_us` is the per-run total
/// of that stage, directly comparable to its step's `mean_us`.
#[derive(Debug, Clone)]
pub struct UnitStat {
    /// Stage label from [`CompiledPlan::step_unit_labels`], e.g.
    /// `"conv2d[1]"`, `"gap[3]"`, `"copy-out"`.
    pub label: String,
    /// Mean per-run wall time of this stage (µs).
    pub mean_us: f64,
    /// MACs this stage performs per run (constant across runs).
    pub macs: u64,
    /// Fraction of the step's summed unit time spent in this stage.
    pub share: f64,
}

/// Aggregated timing of one step across profiled runs.
#[derive(Debug, Clone)]
pub struct StepStat {
    pub meta: StepMeta,
    /// MACs per run (constant — the step list is static).
    pub macs: u64,
    pub mean_us: f64,
    pub p50_us: f64,
    pub p95_us: f64,
    pub min_us: f64,
    pub max_us: f64,
    /// This step's fraction of the whole run's mean wall time.
    pub share: f64,
    /// Per-unit breakdown of fused spans (empty for stash/single steps,
    /// or when the profiler recorded no unit brackets).
    pub units: Vec<UnitStat>,
}

/// Per-step attribution of a compiled plan, aggregated over `runs`
/// profiled executions.
#[derive(Debug, Clone)]
pub struct StepProfile {
    /// Canonical model name of the profiled plan.
    pub model: String,
    /// The fusion setting's span layout (`FusionSetting::describe`).
    pub setting: String,
    /// Profiled runs aggregated into each step's statistics.
    pub runs: usize,
    /// Sum of per-step mean latencies — the mean in-plan wall time.
    pub total_mean_us: f64,
    /// One entry per compiled step, in execution order.
    pub steps: Vec<StepStat>,
}

impl StepProfile {
    /// Aggregate a recorder's samples against the plan's step metadata.
    /// Panics if the recorder has recorded no runs or belongs to a
    /// different plan (step-count mismatch).
    pub fn from_recorder(compiled: &CompiledPlan, rec: &StepRecorder) -> Self {
        let metas = compiled.step_metas();
        assert_eq!(metas.len(), rec.samples_us.len(), "recorder/plan step mismatch");
        let runs = rec.runs();
        assert!(runs > 0, "no profiled runs recorded");
        let mut steps: Vec<StepStat> = metas
            .into_iter()
            .enumerate()
            .map(|(i, meta)| {
                let mut sorted = rec.samples_us(i).to_vec();
                sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
                let mean = sorted.iter().sum::<f64>() / sorted.len() as f64;
                StepStat {
                    meta,
                    macs: rec.macs(i),
                    mean_us: mean,
                    p50_us: nearest_rank(&sorted, 0.50),
                    p95_us: nearest_rank(&sorted, 0.95),
                    min_us: sorted[0],
                    max_us: *sorted.last().unwrap(),
                    share: 0.0,
                    units: Vec::new(),
                }
            })
            .collect();
        let total: f64 = steps.iter().map(|s| s.mean_us).sum();
        for s in &mut steps {
            s.share = if total > 0.0 { s.mean_us / total } else { 0.0 };
        }
        // Per-unit attribution inside fused spans: the recorder holds
        // *totals* across rows and runs per unit index; divide by runs
        // for per-run means (MAC totals divide exactly — unit MACs are
        // constant per run).
        let unit_labels = compiled.step_unit_labels();
        for (i, s) in steps.iter_mut().enumerate() {
            let us = &rec.unit_us[i];
            if us.is_empty() {
                continue;
            }
            let unit_total: f64 = us.iter().sum();
            s.units = us
                .iter()
                .enumerate()
                .map(|(u, &t)| UnitStat {
                    label: unit_labels[i]
                        .get(u)
                        .cloned()
                        .unwrap_or_else(|| format!("unit[{u}]")),
                    mean_us: t / runs as f64,
                    macs: rec.unit_macs[i].get(u).copied().unwrap_or(0) / runs as u64,
                    share: if unit_total > 0.0 { t / unit_total } else { 0.0 },
                })
                .collect();
        }
        Self {
            model: compiled.model().name.clone(),
            setting: compiled.setting().describe(),
            runs,
            total_mean_us: total,
            steps,
        }
    }

    /// The `k` steps with the largest mean latency, descending — the
    /// "where does the time go" view kernel work starts from.
    pub fn top_k(&self, k: usize) -> Vec<&StepStat> {
        let mut by_time: Vec<&StepStat> = self.steps.iter().collect();
        by_time.sort_by(|a, b| b.mean_us.partial_cmp(&a.mean_us).unwrap());
        by_time.truncate(k);
        by_time
    }

    /// Total MACs of one run (sum over steps).
    pub fn total_macs(&self) -> u64 {
        self.steps.iter().map(|s| s.macs).sum()
    }
}

/// Profile `compiled` over `runs` executions of `input`: one warm-up
/// run (unprofiled — pool faulting and cache warm-up would otherwise
/// skew the first sample), then `runs` profiled runs in a dedicated
/// pool. Returns the aggregated per-step attribution.
pub fn profile_plan(compiled: &CompiledPlan, input: &Tensor, runs: usize) -> StepProfile {
    let runs = runs.max(1);
    let mut pool = compiled.make_pool();
    let mut out = vec![0.0f32; compiled.output_len()];
    compiled.run_into(input.as_map(), &mut pool, &mut out);
    let mut rec = StepRecorder::new(compiled.num_steps());
    for _ in 0..runs {
        compiled.run_profiled(input.as_map(), &mut pool, &mut out, &mut rec);
    }
    StepProfile::from_recorder(compiled, &rec)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::ParamGen;
    use crate::optimizer::Planner;
    use crate::zoo;

    fn profiled(model: crate::model::ModelChain, runs: usize) -> (StepProfile, CompiledPlan) {
        let setting = Planner::for_model(model.clone()).setting().unwrap();
        let compiled = CompiledPlan::compile(model, setting);
        let s = compiled.model().shapes[0];
        let x = Tensor::from_data(
            s.h as usize,
            s.w as usize,
            s.c as usize,
            ParamGen::new(7).fill(s.elems() as usize, 2.0),
        );
        (profile_plan(&compiled, &x, runs), compiled)
    }

    #[test]
    fn profile_covers_every_step_and_shares_sum_to_one() {
        let (p, compiled) = profiled(zoo::quickstart(), 12);
        assert_eq!(p.steps.len(), compiled.num_steps());
        assert_eq!(p.runs, 12);
        assert!(p.total_mean_us > 0.0);
        let share_sum: f64 = p.steps.iter().map(|s| s.share).sum();
        assert!((share_sum - 1.0).abs() < 1e-9, "{share_sum}");
        for s in &p.steps {
            assert!(s.min_us <= s.p50_us && s.p50_us <= s.p95_us && s.p95_us <= s.max_us);
            assert!(s.meta.bytes > 0, "step '{}' reports no bytes", s.meta.label);
        }
    }

    #[test]
    fn profiled_macs_match_unprofiled_run() {
        let (p, compiled) = profiled(zoo::kws_cnn(), 3);
        let s = compiled.model().shapes[0];
        let x = Tensor::from_data(
            s.h as usize,
            s.w as usize,
            s.c as usize,
            ParamGen::new(7).fill(s.elems() as usize, 2.0),
        );
        let mut pool = compiled.make_pool();
        let mut out = vec![0.0f32; compiled.output_len()];
        let macs = compiled.run_into(x.as_map(), &mut pool, &mut out);
        assert_eq!(p.total_macs(), macs);
    }

    #[test]
    fn fused_steps_expose_per_unit_attribution() {
        let (p, compiled) = profiled(zoo::kws_cnn(), 4);
        let labels = compiled.step_unit_labels();
        assert_eq!(labels.len(), p.steps.len());
        let mut saw_fused = false;
        for (s, ls) in p.steps.iter().zip(&labels) {
            if s.meta.kind == "fused" || s.meta.kind == "fused-iter" {
                saw_fused = true;
                assert_eq!(s.units.len(), ls.len(), "step '{}'", s.meta.label);
                for (u, l) in s.units.iter().zip(ls) {
                    assert_eq!(&u.label, l);
                }
                let share_sum: f64 = s.units.iter().map(|u| u.share).sum();
                assert!((share_sum - 1.0).abs() < 1e-9, "{share_sum}");
                let unit_macs: u64 = s.units.iter().map(|u| u.macs).sum();
                assert_eq!(unit_macs, s.macs, "step '{}'", s.meta.label);
            } else {
                assert!(s.units.is_empty(), "step '{}'", s.meta.label);
            }
        }
        assert!(saw_fused, "kws plan has no fused step");
    }

    #[test]
    fn top_k_is_descending_and_truncated() {
        let (p, _) = profiled(zoo::quickstart(), 5);
        let top = p.top_k(2);
        assert!(top.len() <= 2);
        if top.len() == 2 {
            assert!(top[0].mean_us >= top[1].mean_us);
        }
        let full = p.top_k(usize::MAX);
        assert_eq!(full.len(), p.steps.len());
    }
}
