//! Property-based integration tests over random models (seeded in-tree
//! runner, `msf_cnn::util::prop` — DESIGN.md §Substitutions).
//!
//! Everything drives the `optimizer::strategy::PlanStrategy` objects —
//! the same trait objects `Planner` and `PlanBatch` dispatch on (the
//! pre-0.2 free functions are gone); `strategy_solves_match_planner`
//! below pins strategy-vs-builder equality on every random model.
//!
//! Invariants locked in:
//! 1. P2 (pruned, polynomial) is *exactly optimal* vs exhaustive
//!    enumeration on small random chains.
//! 2. P1 (pruned) is feasible whenever the exhaustive optimum exists and
//!    never violates its F_max budget.
//! 3. Executed fused settings match vanilla numerics.
//! 4. Executed MACs match the Eq. 12–15 predictions within tolerance.
//! 5. The baselines are never strictly better than msf-CNN on peak RAM.
//! 6. Monotonicity: looser budgets never yield worse optima.

use msf_cnn::exec::Engine;
use msf_cnn::graph::{enumerate_paths, DagOptions, FusionDag};
use msf_cnn::memory::Arena;
use msf_cnn::model::{Activation, Layer, ModelChain, TensorShape};
use msf_cnn::ops::Tensor;
use msf_cnn::optimizer::strategy::{HeadFusion, P1, P2, StreamNet, Vanilla};
use msf_cnn::optimizer::{
    exhaustive_p1, exhaustive_p2, Constraint, Constraints, FusionSetting, PlanStrategy,
};
use msf_cnn::util::prop::{check, Gen};

/// P1 via the strategy surface: min peak RAM s.t. `F <= f_max`.
fn min_ram(dag: &FusionDag, f_max: f64) -> Option<FusionSetting> {
    P1.solve(dag, &Constraints::none().with(Constraint::Overhead(f_max)))
}

/// Unconstrained P1 via the strategy surface.
fn min_ram_unconstrained(dag: &FusionDag) -> Option<FusionSetting> {
    P1.solve(dag, &Constraints::none())
}

/// P2 via the strategy surface: min MACs s.t. peak RAM `<= p_max`.
fn min_macs(dag: &FusionDag, p_max_bytes: u64) -> Option<FusionSetting> {
    P2.solve(dag, &Constraints::none().with(Constraint::Ram(p_max_bytes)))
}

/// A random fusable CNN chain: 3-7 conv/dw/pool layers + optional
/// pool/dense tail, sized so exhaustive enumeration stays tractable.
/// Inputs deliberately cover square, mildly rectangular, and KWS-style
/// tall-thin / wide-short aspect ratios so the Eq. 5/11 h-vs-w clamps are
/// exercised off the square happy path.
fn random_chain(g: &mut Gen) -> ModelChain {
    let depth = g.usize_in(3, 7);
    let mut layers: Vec<Layer> = Vec::new();
    let mut c = *g.pick(&[1u32, 3, 4]);
    let (mut h, mut w) = match g.usize_in(0, 3) {
        // Tall-thin spectrogram (49×10-like): bands outgrow the width.
        0 => (g.u32_in(40, 56), g.u32_in(8, 12)),
        // Wide-short (rotated spectrogram).
        1 => (g.u32_in(8, 12), g.u32_in(40, 56)),
        // Square-ish / mildly rectangular.
        _ => (g.u32_in(14, 28), g.u32_in(14, 28)),
    };
    let input = TensorShape::new(h, w, c);
    for i in 0..depth {
        let kind = g.usize_in(0, 9);
        let (layer, stride, k, c_next): (Layer, u32, u32, u32) = match kind {
            0..=4 => {
                let k = *g.pick(&[1u32, 3]);
                let s = if k == 1 { 1 } else { *g.pick(&[1u32, 2]) };
                let p = if k == 3 && g.bool() { 1 } else { 0 };
                let cout = *g.pick(&[2u32, 4, 8]);
                let l = Layer::conv(format!("c{i}"), k, s, p, c, cout, Activation::Relu6);
                (l, s, k, cout)
            }
            5..=7 => {
                let s = *g.pick(&[1u32, 2]);
                (Layer::dwconv(format!("d{i}"), 3, s, 1, c, Activation::Relu6), s, 3, c)
            }
            _ => (Layer::avg_pool(format!("p{i}"), 2, 2, c), 2, 2, c),
        };
        // Keep spatial dims valid; only commit the layer (and its channel
        // change) when it fits.
        let pad = layer.padding;
        if h + 2 * pad < k || w + 2 * pad < k {
            break;
        }
        let h2 = (h + 2 * pad - k) / stride + 1;
        let w2 = (w + 2 * pad - k) / stride + 1;
        if h2 < 3 || w2 < 3 {
            break;
        }
        h = h2;
        w = w2;
        c = c_next;
        layers.push(layer);
    }
    if layers.len() < 2 {
        layers.push(Layer::conv("fallback", 3, 1, 1, c, 4, Activation::Relu6));
        c = 4;
    }
    if g.bool() {
        layers.push(Layer::global_pool("gp", c));
        layers.push(Layer::dense("fc", c, g.u32_in(2, 10)));
    }
    ModelChain::new("rand", input, layers)
}

#[test]
fn p2_exactly_matches_exhaustive() {
    check("p2-vs-exhaustive", 40, |g| {
        let m = random_chain(g);
        let dag = FusionDag::build(&m, DagOptions::default());
        if enumerate_paths(&dag).len() > 4096 {
            return Ok(()); // keep exhaustive tractable
        }
        let p_max = (m.vanilla_peak_ram() as f64 * g.f32_in(0.05, 1.2) as f64) as u64;
        match (min_macs(&dag, p_max), exhaustive_p2(&dag, p_max)) {
            (None, None) => Ok(()),
            (Some(f), Some(s)) if f.cost.macs == s.cost.macs => Ok(()),
            (f, s) => Err(format!(
                "P_max={p_max}: fast {:?} vs exact {:?}",
                f.map(|x| x.cost.macs),
                s.map(|x| x.cost.macs)
            )),
        }
    });
}

#[test]
fn p1_feasible_and_budget_respected() {
    check("p1-feasibility", 40, |g| {
        let m = random_chain(g);
        let dag = FusionDag::build(&m, DagOptions::default());
        if enumerate_paths(&dag).len() > 4096 {
            return Ok(());
        }
        let f_max = 1.0 + g.f32_in(0.02, 1.5) as f64;
        match (min_ram(&dag, f_max), exhaustive_p1(&dag, f_max)) {
            (None, None) => Ok(()),
            (None, Some(_)) => Err(format!("missed feasible solution at F_max={f_max}")),
            (Some(_), None) => Err(format!("fabricated solution at F_max={f_max}")),
            (Some(f), Some(s)) => {
                if f.cost.overhead > f_max + 1e-9 {
                    return Err(format!("budget violated: {} > {f_max}", f.cost.overhead));
                }
                if f.cost.peak_ram < s.cost.peak_ram {
                    return Err("pruned beat the exact optimum?!".into());
                }
                Ok(())
            }
        }
    });
}

#[test]
fn fused_execution_matches_vanilla() {
    check("fused-vs-vanilla-numerics", 25, |g| {
        let m = random_chain(g);
        let dag = FusionDag::build(&m, DagOptions::default());
        let engine = Engine::new(m.clone());
        let shape = m.shapes[0];
        let input = Tensor::from_data(
            shape.h as usize,
            shape.w as usize,
            shape.c as usize,
            g.vec_f32(shape.elems() as usize, 2.0),
        );
        let Some(fused) = min_ram_unconstrained(&dag) else {
            return Err("no setting".into());
        };
        let mut a1 = Arena::unbounded();
        let mut a2 = Arena::unbounded();
        let rv = engine
            .run(&Vanilla.solve(&dag, &Constraints::none()).unwrap(), &input, &mut a1)
            .map_err(|e| e.to_string())?;
        let rf = engine.run(&fused, &input, &mut a2).map_err(|e| e.to_string())?;
        let max_diff = rv
            .output
            .iter()
            .zip(&rf.output)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        if max_diff > 1e-2 {
            return Err(format!("outputs diverge by {max_diff} for {}", fused.describe()));
        }
        if a1.live_bytes() != 0 || a2.live_bytes() != 0 {
            return Err("arena leak".into());
        }
        Ok(())
    });
}

#[test]
fn executed_macs_match_prediction() {
    check("macs-vs-eq12-15", 25, |g| {
        let m = random_chain(g);
        let dag = FusionDag::build(&m, DagOptions::default());
        let engine = Engine::new(m.clone());
        let shape = m.shapes[0];
        let input = Tensor::from_data(
            shape.h as usize,
            shape.w as usize,
            shape.c as usize,
            g.vec_f32(shape.elems() as usize, 1.0),
        );
        let Some(s) = min_ram_unconstrained(&dag) else {
            return Err("no setting".into());
        };
        let mut arena = Arena::unbounded();
        let r = engine.run(&s, &input, &mut arena).map_err(|e| e.to_string())?;
        let ratio = r.macs as f64 / s.cost.macs as f64;
        // Eq. 12's floor-rounded tile count is approximate at map edges,
        // and the approximation compounds with block depth; on the tiny
        // random maps used here (14–28 px, up to depth-7 blocks) those
        // edge rows are a visible fraction, so the envelope is wide. The
        // `fused_macs_match_analytical_model` unit test pins the <=10%
        // case on realistic maps, and `no_overlap_means_no_overhead` pins
        // the exact case.
        if !(0.4..=1.5).contains(&ratio) {
            return Err(format!(
                "measured {} vs predicted {} (ratio {ratio:.3}) for {}",
                r.macs,
                s.cost.macs,
                s.describe()
            ));
        }
        Ok(())
    });
}

#[test]
fn msf_dominates_baselines_on_ram() {
    check("msf-dominates", 40, |g| {
        let m = random_chain(g);
        let dag = FusionDag::build(&m, DagOptions::default());
        let Some(msf) = min_ram_unconstrained(&dag) else {
            return Err("no setting".into());
        };
        let none = Constraints::none();
        let h = HeadFusion.solve(&dag, &none).unwrap();
        let v = Vanilla.solve(&dag, &none).unwrap();
        if msf.cost.peak_ram > h.cost.peak_ram {
            return Err(format!("heuristic beat msf: {} < {}", h.cost.peak_ram, msf.cost.peak_ram));
        }
        if msf.cost.peak_ram > v.cost.peak_ram {
            return Err("vanilla beat msf".into());
        }
        if let Some(sn) = StreamNet.solve(&dag, &none) {
            if msf.cost.peak_ram > sn.cost.peak_ram {
                return Err(format!(
                    "streamnet beat msf: {} < {}",
                    sn.cost.peak_ram, msf.cost.peak_ram
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn budgets_are_monotone() {
    check("budget-monotonicity", 25, |g| {
        let m = random_chain(g);
        let dag = FusionDag::build(&m, DagOptions::default());
        // P2: larger P_max => no more MACs.
        let p1 = (m.vanilla_peak_ram() as f64 * 0.3) as u64;
        let p2 = (m.vanilla_peak_ram() as f64 * 0.9) as u64;
        if let (Some(tight), Some(loose)) =
            (min_macs(&dag, p1), min_macs(&dag, p2))
        {
            if loose.cost.macs > tight.cost.macs {
                return Err("P2 not monotone".into());
            }
        }
        // P1: larger F_max => no more RAM.
        if let (Some(tight), Some(loose)) =
            (min_ram(&dag, 1.1), min_ram(&dag, 2.5))
        {
            if loose.cost.peak_ram > tight.cost.peak_ram {
                return Err("P1 not monotone".into());
            }
        }
        Ok(())
    });
}

#[test]
fn nonsquare_dwconv_chain_matches_exhaustive() {
    // Deterministic KWS-family chains (tall-thin input, depthwise +
    // pointwise layers, stride-2 downsampling) checked against exhaustive
    // enumeration across both constraint grids — the off-square,
    // off-plain-conv corner the random generator only sometimes hits.
    for (hh, ww) in [(49u32, 10u32), (10, 49)] {
        let m = ModelChain::new(
            "kws-prop",
            TensorShape::new(hh, ww, 1),
            vec![
                Layer::conv("c0", 3, 1, 1, 1, 8, Activation::Relu6),
                Layer::dwconv("dw1", 3, 2, 1, 8, Activation::Relu6),
                Layer::conv("pw1", 1, 1, 0, 8, 16, Activation::Relu6),
                Layer::dwconv("dw2", 3, 2, 1, 16, Activation::Relu6),
                Layer::global_pool("gp", 16),
                Layer::dense("fc", 16, 6),
            ],
        );
        let dag = FusionDag::build(&m, DagOptions::default());
        for p_max in [1_000u64, 2_000, 4_000, m.vanilla_peak_ram()] {
            match (min_macs(&dag, p_max), exhaustive_p2(&dag, p_max)) {
                (None, None) => {}
                (Some(f), Some(s)) => {
                    assert_eq!(f.cost.macs, s.cost.macs, "{hh}x{ww} P_max={p_max}")
                }
                (f, s) => panic!("{hh}x{ww} P_max={p_max}: {f:?} vs {s:?}"),
            }
        }
        for f_max in [1.05f64, 1.3, 2.0] {
            match (min_ram(&dag, f_max), exhaustive_p1(&dag, f_max)) {
                (None, None) => {}
                (Some(f), Some(s)) => {
                    assert!(f.cost.overhead <= f_max + 1e-9, "{hh}x{ww}");
                    assert!(f.cost.peak_ram >= s.cost.peak_ram, "pruned beat exact?!");
                }
                (f, s) => panic!("{hh}x{ww} F_max={f_max}: {f:?} vs {s:?}"),
            }
        }
    }
}

#[test]
fn plan_batch_parallel_matches_serial_on_random_models() {
    use msf_cnn::optimizer::{PlanBatch, PlanJob, PlanObjective};
    check("plan-batch-equivalence", 8, |g| {
        let mut batch = PlanBatch::new();
        for i in 0..3 {
            let m = random_chain(g);
            let p_mid = (m.vanilla_peak_ram() as f64 * 0.4) as u64;
            let idx = batch.add_model(format!("rand{i}"), m);
            batch.push(PlanJob::new(idx, PlanObjective::Vanilla));
            batch.push(PlanJob::new(idx, PlanObjective::Heuristic));
            batch.push(PlanJob::new(idx, PlanObjective::StreamNet));
            batch.push(PlanJob::new(idx, PlanObjective::MinRam { f_max: 1.2 }));
            batch.push(PlanJob::new(idx, PlanObjective::MinRam { f_max: f64::INFINITY }));
            batch.push(PlanJob::new(idx, PlanObjective::MinMacs { p_max_bytes: p_mid }));
        }
        let serial = batch.solve_serial();
        let parallel = batch.solve_with_threads(4);
        if serial.len() != parallel.len() {
            return Err("length mismatch".into());
        }
        for (s, p) in serial.iter().zip(&parallel) {
            let same = match (&s.setting, &p.setting) {
                (None, None) => true,
                (Some(a), Some(b)) => {
                    a.spans == b.spans
                        && a.cost.peak_ram == b.cost.peak_ram
                        && a.cost.macs == b.cost.macs
                }
                _ => false,
            };
            if !same {
                return Err(format!(
                    "parallel diverged on model {} {:?}",
                    s.job.model, s.job.objective
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn strategy_solves_match_planner_pipeline() {
    // Solving a strategy by hand on the raw DAG and driving it through
    // the Planner builder (cached DAG + memoized edge costs) must be two
    // names for the same solver, on every random model.
    use msf_cnn::optimizer::Planner;
    check("strategies-vs-planner", 25, |g| {
        let m = random_chain(g);
        let dag = FusionDag::build(&m, DagOptions::default());
        let mut planner = Planner::for_model(m.clone());
        let none = Constraints::none();
        let p_mid = (m.vanilla_peak_ram() as f64 * 0.4) as u64;
        let cases: [(&dyn PlanStrategy, Constraints); 6] = [
            (&P1, none),
            (&P1, none.with(Constraint::Overhead(1.2))),
            (&P2, none.with(Constraint::Ram(p_mid))),
            (&Vanilla, none),
            (&HeadFusion, none),
            (&StreamNet, none),
        ];
        for (strategy, constraints) in cases {
            let direct = strategy.solve(&dag, &constraints);
            let via_planner = planner.plan_with(strategy, constraints).ok().map(|p| p.setting);
            let same = match (&direct, &via_planner) {
                (None, None) => true,
                (Some(a), Some(b)) => {
                    a.spans == b.spans
                        && a.cost.peak_ram == b.cost.peak_ram
                        && a.cost.macs == b.cost.macs
                }
                _ => false,
            };
            if !same {
                return Err(format!(
                    "{} diverged from the planner: {:?} vs {:?}",
                    strategy.name(),
                    direct.as_ref().map(|x| x.cost.peak_ram),
                    via_planner.as_ref().map(|x| x.cost.peak_ram)
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn complete_dag_path_count_follows_appendix_d() {
    // 2^{V-2} complete paths on fully-fusable chains (App. D) — via the
    // real builder on purely-conv models (all spans fusable).
    for n in 2..9usize {
        let layers = (0..n)
            .map(|i| Layer::conv(format!("c{i}"), 1, 1, 0, 2, 2, Activation::None))
            .collect();
        let m = ModelChain::new("k", TensorShape::new(6, 6, 2), layers);
        let dag = FusionDag::build(&m, DagOptions::default());
        // n layers => V = n+1 nodes => 2^{V-2} = 2^{n-1} complete paths.
        assert_eq!(enumerate_paths(&dag).len(), 1usize << (n - 1));
    }
}
