//! Backend parity and plan round-trip serving — the acceptance surface of
//! the unified Planner/Backend API.
//!
//! * The two executors ([`msf_cnn::exec::Engine`] behind
//!   [`EngineBackend`], [`msf_cnn::runtime::Runtime`] behind
//!   [`ArtifactBackend`]) must produce identical logits and consistent
//!   `peak_ram()` for the quickstart model when driven through the one
//!   [`InferBackend`] trait. (Artifact halves skip when `artifacts/` has
//!   not been built — `make artifacts` is the build-time Python step.)
//! * A [`Plan`] solved and saved by the [`Planner`] must load from disk
//!   and serve through [`MultiModelServer`] without re-running the
//!   optimizer.

use msf_cnn::backend::{ArtifactBackend, BackendSpec, EngineBackend, InferBackend};
use msf_cnn::coordinator::{ModelSpec, MultiModelServer};
use msf_cnn::exec::Engine;
use msf_cnn::memory::Arena;
use msf_cnn::ops::{ParamGen, Tensor};
use msf_cnn::optimizer::{strategy, Constraint, Constraints, Planner};
use msf_cnn::zoo;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

fn quickstart_input(seed: u64) -> Vec<f32> {
    ParamGen::new(seed).fill(32 * 32 * 3, 2.0)
}

fn tmp_plan_path(name: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("msfcnn-{name}-{}.plan.json", std::process::id()))
}

// ------------------------------------------------------------ engine backend

#[test]
fn engine_backend_matches_direct_engine_execution() {
    let model = zoo::quickstart();
    let plan = Planner::for_model(model.clone()).plan().unwrap();

    let mut backend = EngineBackend::new(model.clone(), plan.setting.clone());
    let x = quickstart_input(11);
    let via_trait = backend.run(&x).unwrap();

    let engine = Engine::new(model);
    let input = Tensor::from_data(32, 32, 3, x);
    let mut arena = Arena::unbounded();
    let direct = engine.run(&plan.setting, &input, &mut arena).unwrap();

    assert_eq!(via_trait, direct.output, "trait must run the plan verbatim");
    assert_eq!(backend.peak_ram(), plan.cost().peak_ram, "analytic peak");
    assert_eq!(backend.measured_peak(), Some(direct.peak_ram), "tracked peak");
}

#[test]
fn engine_backends_expose_consistent_peaks_across_strategies() {
    // Through one trait, the P1 plan must dominate the baselines on the
    // analytic peak — the Table 2 ordering, now visible at the serving
    // surface.
    let mut planner = Planner::for_model(zoo::quickstart());
    let msf = planner.plan().unwrap();
    let vanilla = planner
        .plan_with(&strategy::Vanilla, Constraints::none())
        .unwrap();
    let msf_backend = EngineBackend::from_plan(&msf).unwrap();
    let vanilla_backend = EngineBackend::from_plan(&vanilla).unwrap();
    assert!(msf_backend.peak_ram() < vanilla_backend.peak_ram());
}

// ------------------------------------------- engine vs artifact (parity)

#[test]
fn engine_and_runtime_agree_through_the_trait() {
    let Some(dir) = artifacts_dir() else { return };
    // The runtime's offline path runs the quickstart model through the
    // same engine with the artifact weights, so logits must agree
    // bit-for-bit with an EngineBackend built from those weights.
    let engine = Engine::quickstart_from_artifacts(&dir).unwrap();
    let mut planner = Planner::for_model(engine.model().clone());
    let fused = planner.setting().unwrap();
    let vanilla = planner
        .plan_with(&strategy::Vanilla, Constraints::none())
        .unwrap()
        .setting;

    let mut artifact_fused: Box<dyn InferBackend> =
        Box::new(ArtifactBackend::open(&dir, "model_fused").unwrap());
    let mut artifact_vanilla: Box<dyn InferBackend> =
        Box::new(ArtifactBackend::open(&dir, "model_vanilla").unwrap());

    for seed in [5u64, 6] {
        let x = quickstart_input(seed);
        let input = Tensor::from_data(32, 32, 3, x.clone());

        let mut a1 = Arena::unbounded();
        let direct_fused = engine.run(&fused, &input, &mut a1).unwrap();
        let mut a2 = Arena::unbounded();
        let direct_vanilla = engine.run(&vanilla, &input, &mut a2).unwrap();

        assert_eq!(artifact_fused.run(&x).unwrap(), direct_fused.output);
        assert_eq!(artifact_vanilla.run(&x).unwrap(), direct_vanilla.output);
    }

    // peak_ram() parity: the artifact backend's fused entry reports the
    // same analytic peak as the engine-side plan for the same model.
    assert_eq!(artifact_fused.peak_ram(), fused.cost.peak_ram);
    assert_eq!(artifact_vanilla.peak_ram(), vanilla.cost.peak_ram);
}

// -------------------------------------------- plan round-trip + serving

#[test]
fn plan_save_load_serve_roundtrip() {
    // The acceptance pipeline: Planner solves under a budget, the Plan is
    // persisted, a fresh process-side load serves it through the
    // multi-model coordinator — no optimizer re-run.
    let plan = Planner::for_model(zoo::quickstart())
        .constraint(Constraint::Ram(8_000))
        .strategy(strategy::P2)
        .plan()
        .unwrap();
    assert!(plan.cost().peak_ram <= 8_000);

    let path = tmp_plan_path("roundtrip");
    plan.save(&path).unwrap();

    let spec = ModelSpec::plan_file("qs", &path).unwrap();
    let loaded = match &spec.backend {
        BackendSpec::Plan { plan: p } => p.clone(),
        other => panic!("expected a plan-backed spec, got {other:?}"),
    };
    assert_eq!(loaded, plan, "JSON round-trip must preserve the plan");

    let server = MultiModelServer::start(vec![spec]).unwrap();
    let handle = server.handle();
    let logits = handle.infer("qs", quickstart_input(42)).unwrap();
    assert_eq!(logits.len(), 10);
    assert!(logits.iter().all(|v| v.is_finite()));

    // The served plan is exactly the persisted one: replies match a
    // direct engine run of the loaded setting.
    let engine = Engine::new(zoo::quickstart());
    let input = Tensor::from_data(32, 32, 3, quickstart_input(42));
    let mut arena = Arena::unbounded();
    let direct = engine.run(&loaded.setting, &input, &mut arena).unwrap();
    assert_eq!(logits, direct.output);

    drop(handle);
    server.shutdown();
    let _ = std::fs::remove_file(&path);
}

#[test]
fn corrupt_plan_file_fails_at_registration() {
    let path = tmp_plan_path("corrupt");
    std::fs::write(&path, "{\"version\": 1}").unwrap();
    assert!(ModelSpec::plan_file("bad", &path).is_err());
    let _ = std::fs::remove_file(&path);
}
