//! Cross-stack integration: the AOT HLO artifacts (L1 Pallas → L2 JAX →
//! HLO text) loaded and executed by the Rust PJRT runtime (L3), and
//! cross-checked against the pure-Rust executor running the *same weights*
//! (`artifacts/weights.json`).
//!
//! These tests skip (not fail) when `artifacts/` has not been built —
//! `make artifacts` is the build-time Python step.

use msf_cnn::exec::Engine;
use msf_cnn::memory::Arena;
use msf_cnn::ops::{ParamGen, Tensor};
use msf_cnn::optimizer::{strategy, Constraints, Planner};
use msf_cnn::runtime::Runtime;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

fn quickstart_input(seed: u64) -> Vec<f32> {
    ParamGen::new(seed).fill(32 * 32 * 3, 2.0)
}

#[test]
fn manifest_lists_all_entries() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::open(&dir).unwrap();
    for entry in ["model_vanilla", "model_fused", "fused_block", "conv2d", "iter_pool", "iter_dense"]
    {
        assert!(rt.manifest().entries.contains_key(entry), "missing {entry}");
    }
}

#[test]
fn fused_artifact_matches_vanilla_artifact() {
    // The msf-CNN schedule transform must be numerically invisible:
    // the fused HLO module and the vanilla HLO module agree on logits.
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::open(&dir).unwrap();
    for seed in [1u64, 2, 3] {
        let x = quickstart_input(seed);
        let v = rt.run_f32("model_vanilla", &x).unwrap();
        let f = rt.run_f32("model_fused", &x).unwrap();
        assert_eq!(v.len(), 10);
        assert_eq!(f.len(), 10);
        for (a, b) in v.iter().zip(&f) {
            assert!((a - b).abs() < 1e-3, "vanilla {a} vs fused {b}");
        }
    }
}

#[test]
fn rust_executor_matches_xla_artifacts() {
    // Same weights, three implementations of the same network:
    // XLA-compiled JAX (+Pallas) vs the pure-Rust patch executor.
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::open(&dir).unwrap();
    let engine = Engine::quickstart_from_artifacts(&dir).unwrap();
    let mut planner = Planner::for_model(engine.model().clone());
    let fused_setting = planner.setting().unwrap();
    let vanilla_setting = planner
        .plan_with(&strategy::Vanilla, Constraints::none())
        .unwrap()
        .setting;

    for seed in [7u64, 8] {
        let x = quickstart_input(seed);
        let xla_out = rt.run_f32("model_vanilla", &x).unwrap();

        let input = Tensor::from_data(32, 32, 3, x.clone());
        let mut arena = Arena::unbounded();
        let rust_vanilla = engine.run(&vanilla_setting, &input, &mut arena).unwrap();
        let mut arena2 = Arena::unbounded();
        let rust_fused = engine.run(&fused_setting, &input, &mut arena2).unwrap();

        for (i, ((xv, rv), rf)) in xla_out
            .iter()
            .zip(&rust_vanilla.output)
            .zip(&rust_fused.output)
            .enumerate()
        {
            assert!((xv - rv).abs() < 1e-2, "logit {i}: xla {xv} vs rust-vanilla {rv}");
            assert!((xv - rf).abs() < 1e-2, "logit {i}: xla {xv} vs rust-fused {rf}");
        }
    }
}

#[test]
fn kernel_artifacts_execute() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::open(&dir).unwrap();

    // iter_pool: [7,7,32] -> [32]; mean of a constant map is the constant.
    let x = vec![0.5f32; 7 * 7 * 32];
    let out = rt.run_f32("iter_pool", &x).unwrap();
    assert_eq!(out.len(), 32);
    for v in &out {
        assert!((v - 0.5).abs() < 1e-5);
    }

    // iter_dense: [32] -> [10]; just shape+finiteness (weights baked).
    let out = rt.run_f32("iter_dense", &vec![0.1f32; 32]).unwrap();
    assert_eq!(out.len(), 10);
    assert!(out.iter().all(|v| v.is_finite()));

    // conv2d: [32,32,3] -> [30,30,8] with relu6 => all in [0, 6].
    let out = rt.run_f32("conv2d", &quickstart_input(5)).unwrap();
    assert_eq!(out.len(), 30 * 30 * 8);
    assert!(out.iter().all(|v| (0.0..=6.0).contains(v)));
}

#[test]
fn runtime_rejects_wrong_input_shape() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::open(&dir).unwrap();
    assert!(rt.run_f32("model_vanilla", &[0.0; 7]).is_err());
    assert!(rt.run_f32("nonexistent_entry", &[0.0; 7]).is_err());
}
