//! End-to-end serving integration: the coordinator driving the PJRT
//! runtime on the AOT artifacts — queue, batching, backpressure, metrics.
//! Skips when artifacts are absent.

use msf_cnn::coordinator::{InferenceServer, ServerConfig};
use msf_cnn::ops::ParamGen;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

#[test]
fn serves_fused_model_end_to_end() {
    let Some(dir) = artifacts_dir() else { return };
    let server = InferenceServer::start(&dir, ServerConfig::default()).unwrap();
    let handle = server.handle();
    let mut gen = ParamGen::new(7);

    let mut outputs = Vec::new();
    for _ in 0..20 {
        let logits = handle.infer(gen.fill(32 * 32 * 3, 2.0)).unwrap();
        assert_eq!(logits.len(), 10);
        assert!(logits.iter().all(|v| v.is_finite()));
        outputs.push(logits);
    }
    // Different inputs -> different logits (the model is actually running).
    assert_ne!(outputs[0], outputs[1]);

    let metrics = handle.metrics();
    let stats = metrics.stats().unwrap();
    assert_eq!(stats.count, 20);
    assert!(stats.p50_us > 0.0);
    drop(handle);
    server.shutdown();
}

#[test]
fn fused_and_vanilla_entries_agree_through_server() {
    let Some(dir) = artifacts_dir() else { return };
    let fused = InferenceServer::start(
        &dir,
        ServerConfig { entry: "model_fused".into(), ..Default::default() },
    )
    .unwrap();
    let vanilla = InferenceServer::start(
        &dir,
        ServerConfig { entry: "model_vanilla".into(), ..Default::default() },
    )
    .unwrap();
    let (hf, hv) = (fused.handle(), vanilla.handle());
    let mut gen = ParamGen::new(9);
    for _ in 0..5 {
        let x = gen.fill(32 * 32 * 3, 2.0);
        let a = hf.infer(x.clone()).unwrap();
        let b = hv.infer(x).unwrap();
        for (f, v) in a.iter().zip(&b) {
            assert!((f - v).abs() < 1e-3, "fused {f} vs vanilla {v}");
        }
    }
    drop(hf);
    drop(hv);
    fused.shutdown();
    vanilla.shutdown();
}

#[test]
fn concurrent_submitters_all_complete() {
    let Some(dir) = artifacts_dir() else { return };
    let server = InferenceServer::start(&dir, ServerConfig::default()).unwrap();
    let mut joins = Vec::new();
    for t in 0..4u64 {
        let h = server.handle();
        joins.push(std::thread::spawn(move || {
            let mut gen = ParamGen::new(100 + t);
            let mut ok = 0;
            for _ in 0..10 {
                if h.infer(gen.fill(32 * 32 * 3, 2.0)).is_ok() {
                    ok += 1;
                }
            }
            ok
        }));
    }
    let total: usize = joins.into_iter().map(|j| j.join().unwrap()).sum();
    assert_eq!(total, 40);
    let m = server.handle().metrics();
    assert!(m.batches() >= 1);
    server.shutdown();
}

#[test]
fn tiny_queue_applies_backpressure() {
    let Some(dir) = artifacts_dir() else { return };
    let server = InferenceServer::start(
        &dir,
        ServerConfig { queue_cap: 1, batch_max: 1, ..Default::default() },
    )
    .unwrap();
    let handle = server.handle();
    let mut gen = ParamGen::new(11);
    // Flood with async submissions; some must bounce off the 1-deep queue.
    let mut pendings = Vec::new();
    let mut rejected = 0;
    for _ in 0..64 {
        match handle.submit(gen.fill(32 * 32 * 3, 2.0)) {
            Ok(p) => pendings.push(p),
            Err(_) => rejected += 1,
        }
    }
    for p in pendings {
        let _ = p.wait();
    }
    // Either we saw rejections live, or the metrics recorded none because
    // the executor kept pace — both acceptable; what must hold is that
    // rejections are *counted* consistently.
    assert_eq!(handle.metrics().rejections(), rejected);
    drop(handle);
    server.shutdown();
}
