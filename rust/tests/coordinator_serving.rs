//! Serving integration: the multi-model coordinator driving engine-backed
//! plans (always) and the AOT artifact runtime (when `artifacts/` has
//! been built) — registry routing, per-model queues/micro-batches,
//! backpressure, structured shutdown drain, and per-model metrics.

use msf_cnn::coordinator::{
    InferenceServer, ModelSpec, MultiModelServer, ServeError, ServerConfig,
};
use msf_cnn::model::ModelChain;
use msf_cnn::ops::ParamGen;
use msf_cnn::optimizer::Planner;
use msf_cnn::zoo;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

/// Engine-backed spec: the model's min-RAM plan run by the pure-Rust
/// executor — no artifacts required.
fn engine_spec(id: &str, model: ModelChain) -> ModelSpec {
    let setting = Planner::for_model(model.clone())
        .setting()
        .expect("min-RAM plan");
    ModelSpec::engine(id, model, setting)
}

fn input_for(model: &ModelChain, seed: u64) -> Vec<f32> {
    ParamGen::new(seed).fill(model.shapes[0].elems() as usize, 2.0)
}

// ---------------------------------------------------------------- multi-model

#[test]
fn serves_two_models_concurrently_with_per_model_metrics() {
    let quickstart = zoo::quickstart();
    let kws = zoo::kws_cnn();
    let server = MultiModelServer::start(vec![
        engine_spec("quickstart", quickstart.clone()),
        engine_spec("kws", kws.clone()),
    ])
    .unwrap();
    let handle = server.handle();
    assert_eq!(handle.model_ids(), vec!["kws".to_string(), "quickstart".to_string()]);

    // 2 client threads per model, 8 blocking requests each, all in flight
    // against both executors at once.
    let mut joins = Vec::new();
    for (id, model, out_len) in
        [("quickstart", &quickstart, 10usize), ("kws", &kws, 12usize)]
    {
        for t in 0..2u64 {
            let h = server.handle();
            let model = model.clone();
            joins.push(std::thread::spawn(move || {
                for r in 0..8u64 {
                    let logits = h.infer(id, input_for(&model, 100 * t + r)).unwrap();
                    assert_eq!(logits.len(), out_len, "{id}");
                    assert!(logits.iter().all(|v| v.is_finite()));
                }
            }));
        }
    }
    for j in joins {
        j.join().unwrap();
    }

    let metrics = handle.metrics();
    for id in ["quickstart", "kws"] {
        let m = metrics.model(id).unwrap_or_else(|| panic!("metrics for {id}"));
        assert_eq!(m.completed(), 16, "{id}");
        assert!(m.batches() >= 1, "{id}");
        assert_eq!(m.queue_depth(), 0, "{id}");
        assert_eq!(m.rejections(), 0, "{id}");
        assert_eq!(m.shutdown_drops(), 0, "{id}");
        let stats = m.stats().unwrap();
        assert_eq!(stats.count, 16);
        assert!(stats.p50_us > 0.0);
    }
    assert_eq!(metrics.stats().unwrap().count, 32);
    drop(handle);
    server.shutdown();
}

#[test]
fn engine_backed_model_replies_match_direct_execution() {
    use msf_cnn::exec::Engine;
    use msf_cnn::memory::Arena;
    use msf_cnn::ops::Tensor;

    let model = zoo::tiny_cnn();
    let setting = Planner::for_model(model.clone()).setting().unwrap();
    let server = MultiModelServer::start(vec![ModelSpec::engine(
        "tiny",
        model.clone(),
        setting.clone(),
    )])
    .unwrap();
    let h = server.handle();

    let x = input_for(&model, 9);
    let served = h.infer("tiny", x.clone()).unwrap();

    let engine = Engine::new(model.clone());
    let s0 = model.shapes[0];
    let t = Tensor::from_data(s0.h as usize, s0.w as usize, s0.c as usize, x);
    let mut arena = Arena::unbounded();
    let direct = engine.run(&setting, &t, &mut arena).unwrap();
    assert_eq!(served, direct.output, "server must run the registered plan verbatim");

    drop(h);
    server.shutdown();
}

#[test]
fn unknown_model_and_bad_input_are_structured() {
    let model = zoo::tiny_cnn();
    let server = MultiModelServer::start(vec![engine_spec("tiny", model)]).unwrap();
    let h = server.handle();

    // Registered models are visible in metrics before any traffic…
    let m0 = h.metrics();
    assert_eq!(m0.model("tiny").unwrap().completed(), 0);
    // …and unregistered ids never pollute the registry.
    let err = h.submit("resnet-900", vec![0.0; 4]).unwrap_err();
    assert!(h.metrics().model("resnet-900").is_none());
    assert_eq!(err, ServeError::UnknownModel { model_id: "resnet-900".into() });

    let err = h.infer("tiny", vec![0.0; 7]).unwrap_err();
    match &err {
        ServeError::Failed { model_id, detail } => {
            assert_eq!(model_id, "tiny");
            assert!(detail.contains("input length"), "{detail}");
        }
        other => panic!("expected Failed, got {other:?}"),
    }
    drop(h);
    server.shutdown();
}

#[test]
fn shutdown_drains_queue_with_structured_replies() {
    // A heavy model and a serial (batch_max = 1) executor: shut down with
    // the queue still loaded and require every queued request to get an
    // explicit ShuttingDown reply, counted in the per-model metrics —
    // not the old opaque "server dropped request".
    let model = zoo::mcunet_vww5();
    let spec = engine_spec("vww5", model.clone()).with_queue(64, 1);
    let server = MultiModelServer::start(vec![spec]).unwrap();
    let handle = server.handle();

    let total = 24usize;
    let mut pendings = Vec::new();
    for i in 0..total {
        pendings.push(handle.submit("vww5", input_for(&model, i as u64)).unwrap());
    }
    server.shutdown();

    let mut ok = 0usize;
    let mut drained = 0usize;
    for p in pendings {
        match p.wait() {
            Ok(out) => {
                assert!(out.iter().all(|v| v.is_finite()));
                ok += 1;
            }
            Err(ServeError::ShuttingDown { model_id }) => {
                assert_eq!(model_id, "vww5");
                drained += 1;
            }
            Err(other) => panic!("unexpected reply: {other}"),
        }
    }
    assert_eq!(ok + drained, total);
    assert!(drained >= 1, "shutdown should have found queued requests");

    let m = handle.metrics();
    let mm = m.model("vww5").unwrap();
    assert_eq!(mm.shutdown_drops(), drained);
    assert_eq!(mm.completed(), ok);
    assert_eq!(mm.queue_depth(), 0, "drain must account every queued slot");

    // Post-shutdown submits fail fast with the structured error.
    let err = handle.submit("vww5", input_for(&model, 99)).unwrap_err();
    assert!(matches!(err, ServeError::ShuttingDown { .. }));
}

#[test]
fn per_model_backpressure_is_isolated() {
    let busy = zoo::mcunet_vww5();
    let idle = zoo::tiny_cnn();
    let server = MultiModelServer::start(vec![
        engine_spec("busy", busy.clone()).with_queue(1, 1),
        engine_spec("idle", idle.clone()).with_queue(64, 8),
    ])
    .unwrap();
    let handle = server.handle();

    let mut pendings = Vec::new();
    let mut rejected = 0usize;
    for i in 0..32 {
        match handle.submit("busy", input_for(&busy, i)) {
            Ok(p) => pendings.push(p),
            Err(ServeError::QueueFull { model_id }) => {
                assert_eq!(model_id, "busy");
                rejected += 1;
            }
            Err(other) => panic!("unexpected submit error: {other}"),
        }
    }
    // The idle model is unaffected by the busy model's backpressure.
    let logits = handle.infer("idle", input_for(&idle, 7)).unwrap();
    assert_eq!(logits.len(), 4);

    for p in pendings {
        let _ = p.wait();
    }
    let m = handle.metrics();
    assert_eq!(m.model("busy").unwrap().rejections(), rejected);
    assert_eq!(m.model("idle").map(|mm| mm.rejections()).unwrap_or(0), 0);
    assert_eq!(m.rejections(), rejected);
    drop(handle);
    server.shutdown();
}

// ------------------------------------------------------- artifact-backed path

#[test]
fn serves_fused_model_end_to_end() {
    let Some(dir) = artifacts_dir() else { return };
    let server = InferenceServer::start(&dir, ServerConfig::default()).unwrap();
    let handle = server.handle();
    let mut gen = ParamGen::new(7);

    let mut outputs = Vec::new();
    for _ in 0..20 {
        let logits = handle.infer(gen.fill(32 * 32 * 3, 2.0)).unwrap();
        assert_eq!(logits.len(), 10);
        assert!(logits.iter().all(|v| v.is_finite()));
        outputs.push(logits);
    }
    // Different inputs -> different logits (the model is actually running).
    assert_ne!(outputs[0], outputs[1]);

    let metrics = handle.metrics();
    let stats = metrics.stats().unwrap();
    assert_eq!(stats.count, 20);
    assert!(stats.p50_us > 0.0);
    drop(handle);
    server.shutdown();
}

#[test]
fn fused_and_vanilla_entries_agree_through_server() {
    let Some(dir) = artifacts_dir() else { return };
    let fused = InferenceServer::start(
        &dir,
        ServerConfig { entry: "model_fused".into(), ..Default::default() },
    )
    .unwrap();
    let vanilla = InferenceServer::start(
        &dir,
        ServerConfig { entry: "model_vanilla".into(), ..Default::default() },
    )
    .unwrap();
    let (hf, hv) = (fused.handle(), vanilla.handle());
    let mut gen = ParamGen::new(9);
    for _ in 0..5 {
        let x = gen.fill(32 * 32 * 3, 2.0);
        let a = hf.infer(x.clone()).unwrap();
        let b = hv.infer(x).unwrap();
        for (f, v) in a.iter().zip(&b) {
            assert!((f - v).abs() < 1e-3, "fused {f} vs vanilla {v}");
        }
    }
    drop(hf);
    drop(hv);
    fused.shutdown();
    vanilla.shutdown();
}

#[test]
fn concurrent_submitters_all_complete() {
    let Some(dir) = artifacts_dir() else { return };
    let server = InferenceServer::start(&dir, ServerConfig::default()).unwrap();
    let mut joins = Vec::new();
    for t in 0..4u64 {
        let h = server.handle();
        joins.push(std::thread::spawn(move || {
            let mut gen = ParamGen::new(100 + t);
            let mut ok = 0;
            for _ in 0..10 {
                if h.infer(gen.fill(32 * 32 * 3, 2.0)).is_ok() {
                    ok += 1;
                }
            }
            ok
        }));
    }
    let total: usize = joins.into_iter().map(|j| j.join().unwrap()).sum();
    assert_eq!(total, 40);
    let m = server.handle().metrics();
    assert!(m.batches() >= 1);
    server.shutdown();
}

#[test]
fn tiny_queue_applies_backpressure() {
    let Some(dir) = artifacts_dir() else { return };
    let server = InferenceServer::start(
        &dir,
        ServerConfig { queue_cap: 1, batch_max: 1, ..Default::default() },
    )
    .unwrap();
    let handle = server.handle();
    let mut gen = ParamGen::new(11);
    // Flood with async submissions; some must bounce off the 1-deep queue.
    let mut pendings = Vec::new();
    let mut rejected = 0;
    for _ in 0..64 {
        match handle.submit(gen.fill(32 * 32 * 3, 2.0)) {
            Ok(p) => pendings.push(p),
            Err(_) => rejected += 1,
        }
    }
    for p in pendings {
        let _ = p.wait();
    }
    // Either we saw rejections live, or the metrics recorded none because
    // the executor kept pace — both acceptable; what must hold is that
    // rejections are *counted* consistently.
    assert_eq!(handle.metrics().rejections(), rejected);
    drop(handle);
    server.shutdown();
}
