//! Regression tests for the Eq. 5 `I_strip` term on non-square inputs.
//!
//! `block_peak_ram_scheme` builds the first layer's live input window as a
//! `t_0`-row × `k_0`-column tile. `t_0` counts *rows* (band height), so it
//! must clamp against the padded map **height**, and the kernel extent
//! `k_0` spans columns, clamping against the padded **width**. The seed
//! had the two clamps swapped, which corrupted Eq. 5 for tall-thin
//! KWS-style spectrogram inputs (49×10) whenever a deep block's receptive
//! band `t_0` exceeded the padded width: the strip was silently truncated
//! to the *width*, under-predicting the peak. These tests fail on the
//! pre-fix code and pin the corrected analytics against the executor's
//! arena measurement.

use msf_cnn::exec::Engine;
use msf_cnn::fusion::{band_heights, block_cache_bytes, block_peak_ram};
use msf_cnn::graph::{DagOptions, FusionDag};
use msf_cnn::memory::Arena;
use msf_cnn::model::{Activation, Layer, ModelChain, TensorShape};
use msf_cnn::ops::{ParamGen, Tensor};
use msf_cnn::optimizer::{FusionSetting, Planner};
use msf_cnn::zoo;

/// KWS-style tall-thin chain whose 3-layer receptive band (`t_0 = 15`)
/// exceeds the padded width (12) but not the padded height (51) — the
/// exact configuration the pre-fix h/w swap truncated.
fn tall_thin() -> ModelChain {
    ModelChain::new(
        "kws-like",
        TensorShape::new(49, 10, 1),
        vec![
            Layer::conv("c0", 3, 2, 1, 1, 4, Activation::Relu6),
            Layer::conv("c1", 3, 2, 1, 4, 4, Activation::Relu6),
            Layer::conv("c2", 3, 2, 1, 4, 4, Activation::Relu6),
        ],
    )
}

#[test]
fn band_exceeding_width_is_not_truncated() {
    let m = tall_thin();
    // Receptive bands through [0,3): t = [15, 7, 3] rows.
    assert_eq!(band_heights(&m, 0, 3, 1), vec![15, 7, 3]);
    // I_strip = t0(=15, < padded height 51) × k0(=3, < padded width 12)
    //         × c0(=1) = 45 bytes. The pre-fix swap clamped t0 by the
    // padded *width* (12), yielding 36 and under-predicting the block.
    // O = v3 = 7×2×4 = 56; Buf = 7·3·4 + 3·3·4 = 120.
    assert_eq!(block_cache_bytes(&m, 0, 3), 120);
    assert_eq!(block_peak_ram(&m, 0, 3, false), 45 + 56 + 120);
}

#[test]
fn analytical_cost_tracks_arena_measurement() {
    // Execute the [0,3) block and pin the measured-vs-predicted
    // relationship on the non-square chain: the full-width band executor
    // holds at least the analytical tile model, and both sides beat the
    // vanilla footprint.
    let m = tall_thin();
    let dag = FusionDag::build(&m, DagOptions::default());
    let e03 = (0..dag.edges.len())
        .find(|&e| dag.edges[e].a == 0 && dag.edges[e].b == 3 && !dag.edges[e].iterative_tail)
        .expect("fused span [0,3) exists");
    let setting = FusionSetting::from_path(&dag, vec![e03]);
    assert_eq!(setting.cost.peak_ram, 45 + 56 + 120);

    let engine = Engine::new(m.clone());
    let s0 = m.shapes[0];
    let input = Tensor::from_data(
        s0.h as usize,
        s0.w as usize,
        s0.c as usize,
        ParamGen::new(21).fill(s0.elems() as usize, 2.0),
    );
    let mut arena = Arena::unbounded();
    let r = engine.run(&setting, &input, &mut arena).unwrap();
    assert!(
        r.peak_ram >= setting.cost.peak_ram,
        "measured {} < predicted {}",
        r.peak_ram,
        setting.cost.peak_ram
    );
    assert!(r.peak_ram < m.vanilla_peak_ram());
}

#[test]
fn kws_zoo_model_reconciles() {
    // The real 49×10 KWS spectrogram model: min-RAM plan must stay within
    // the band/tile structural factor of the measurement (the
    // exec_reconcile envelope) — with the pre-fix under-prediction the
    // analytical side shrinks and the envelope drifts.
    let m = zoo::kws_cnn();
    let s = Planner::for_model(m.clone()).plan().unwrap().setting;
    let engine = Engine::new(m.clone());
    let s0 = m.shapes[0];
    let input = Tensor::from_data(
        s0.h as usize,
        s0.w as usize,
        s0.c as usize,
        ParamGen::new(5).fill(s0.elems() as usize, 2.0),
    );
    let mut arena = Arena::unbounded();
    let r = engine.run(&s, &input, &mut arena).unwrap();
    assert!(r.peak_ram >= s.cost.peak_ram);
    assert!(r.peak_ram < m.vanilla_peak_ram());
    assert!(r.peak_ram <= s.cost.peak_ram * (m.shapes[0].w as u64).max(8));
}

#[test]
fn transposed_input_clamps_on_its_own_height() {
    // Rotate the spectrogram (10×49): now the padded *height* (12) is the
    // binding clamp for the same 3-layer band, and the strip widens to the
    // full kernel over the long axis — the two orientations must not
    // produce mirrored (swapped-clamp) results.
    let tall = tall_thin();
    let wide = ModelChain::new(
        "kws-rot",
        TensorShape::new(10, 49, 1),
        tall.layers.clone(),
    );
    // t0 = 15 clamps to the padded height 10 + 2 = 12.
    let t = band_heights(&wide, 0, 3, 1);
    assert_eq!(t[0], 15);
    let strip_rows = (t[0] as u64).min(10 + 2);
    let strip = strip_rows * 3 * 1;
    let o = wide.tensor_bytes(3);
    let buf = block_cache_bytes(&wide, 0, 3);
    assert_eq!(block_peak_ram(&wide, 0, 3, false), strip + o + buf);
}
