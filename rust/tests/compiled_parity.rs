//! Compiled-vs-interpreted parity: the compile-once executor
//! (`exec::CompiledPlan`) must be **bit-identical** to the interpreted
//! `exec::Engine` — same logits, same MAC count — across zoo models and
//! every `PlanStrategy`, and its static pool must tell a consistent
//! memory story (watermark == interpreted arena peak <= serialized
//! `Plan` pool size).

use msf_cnn::exec::Engine;
use msf_cnn::memory::Arena;
use msf_cnn::model::ModelChain;
use msf_cnn::ops::{ParamGen, Tensor};
use msf_cnn::optimizer::{strategy, Constraint, Constraints, Plan, Planner, PlanStrategy};
use msf_cnn::zoo;

fn input_for(m: &ModelChain, seed: u64) -> Tensor {
    let s = m.shapes[0];
    Tensor::from_data(
        s.h as usize,
        s.w as usize,
        s.c as usize,
        ParamGen::new(seed).fill(s.elems() as usize, 2.0),
    )
}

/// Interpreted vs compiled on one plan; asserts the full parity contract.
fn assert_parity(engine: &Engine, plan: &Plan, x: &Tensor, tag: &str) {
    let mut arena = Arena::unbounded();
    let interp = engine.run(&plan.setting, x, &mut arena).unwrap();
    let compiled = engine.compile(&plan.setting);
    let mut pool = compiled.make_pool();
    let rep = compiled.run(x, &mut pool);

    assert_eq!(rep.output, interp.output, "{tag}: logits diverged");
    assert_eq!(rep.macs, interp.macs, "{tag}: MAC counts diverged");
    assert_eq!(
        rep.peak_ram, interp.peak_ram,
        "{tag}: compiled watermark != interpreted arena peak"
    );

    // The serialized plan's memory map bounds what execution measured.
    let layout = plan.pool.as_ref().expect("planner records the pool layout");
    assert_eq!(layout.watermark, rep.peak_ram, "{tag}: layout watermark drifted");
    assert!(
        rep.peak_ram <= layout.pool_bytes,
        "{tag}: measured pool peak {} exceeds static pool {}",
        rep.peak_ram,
        layout.pool_bytes
    );

    // A second run on the warm pool is deterministic (no state leaks
    // between requests).
    let rep2 = compiled.run(x, &mut pool);
    assert_eq!(rep2.output, rep.output, "{tag}: warm rerun diverged");
    assert_eq!(rep2.macs, rep.macs, "{tag}");
}

#[test]
fn small_zoo_times_all_strategies_bit_identical() {
    let strategies: [(&str, &dyn PlanStrategy); 5] = [
        ("p1", &strategy::P1),
        ("p2", &strategy::P2),
        ("vanilla", &strategy::Vanilla),
        ("head-fusion", &strategy::HeadFusion),
        ("streamnet", &strategy::StreamNet),
    ];
    for name in ["quickstart", "tiny", "lenet", "kws"] {
        let m = zoo::by_name(name).unwrap();
        let engine = Engine::new(m.clone());
        let x = input_for(&m, 17);
        let mut planner = Planner::for_model(m.clone());
        for (sname, s) in strategies {
            let plan = planner.plan_with(s, Constraints::none()).unwrap();
            assert_parity(&engine, &plan, &x, &format!("{name}/{sname}"));
        }
    }
}

#[test]
fn paper_model_parity_on_fused_strategies() {
    // MN2-vww5 is the expensive residual backbone; cover the two
    // maximally-fused strategies (the vanilla/P2 paths are exercised on
    // the small models above — running all five here would dominate the
    // suite's wall clock for no extra coverage).
    let m = zoo::mcunet_vww5();
    let engine = Engine::new(m.clone());
    let x = input_for(&m, 23);
    let mut planner = Planner::for_model(m.clone());
    for (sname, s) in [
        ("p1", &strategy::P1 as &dyn PlanStrategy),
        ("streamnet", &strategy::StreamNet),
    ] {
        let plan = planner.plan_with(s, Constraints::none()).unwrap();
        assert_parity(&engine, &plan, &x, &format!("mn2-vww5/{sname}"));
    }
}

#[test]
fn budgeted_p2_plans_stay_bit_identical() {
    // Constrained solves route through the same compiled path.
    let m = zoo::quickstart();
    let engine = Engine::new(m.clone());
    let x = input_for(&m, 31);
    let mut planner = Planner::for_model(m.clone());
    for p_max in [4_000u64, 6_000, 12_000] {
        let c = Constraints::none().with(Constraint::Ram(p_max));
        if let Ok(plan) = planner.plan_with(&strategy::P2, c) {
            assert_parity(&engine, &plan, &x, &format!("quickstart/p2@{p_max}"));
        }
    }
}

#[test]
fn serialized_plan_roundtrip_serves_identically() {
    // Save -> load -> compile must produce the same logits as the
    // in-memory plan (the registry deploy path).
    let m = zoo::tiny_cnn();
    let engine = Engine::new(m.clone());
    let x = input_for(&m, 41);
    let plan = Planner::for_model(m.clone()).plan().unwrap();
    let path = std::env::temp_dir().join("msfcnn-compiled-parity.plan.json");
    plan.save(&path).unwrap();
    let loaded = Plan::load(&path).unwrap();
    let _ = std::fs::remove_file(&path);
    assert_eq!(loaded.pool, plan.pool);

    let c1 = engine.compile(&plan.setting);
    let c2 = engine.compile(&loaded.setting);
    let (mut p1, mut p2) = (c1.make_pool(), c2.make_pool());
    assert_eq!(c1.run(&x, &mut p1).output, c2.run(&x, &mut p2).output);
}
