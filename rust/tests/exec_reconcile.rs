//! Reconciliation: measured (executed, arena-tracked) peak RAM vs the
//! analytical Eq. 5–6 encoding the optimizer plans with.
//!
//! The analytical model is the *paper's* model (square Eq. 11 tiles,
//! line-buffer caches); the executor runs full-width band pyramids, which
//! hold strictly more per iteration. These tests pin the relationship:
//! measured >= predicted for fused settings, exactly equal for vanilla,
//! and both far below the vanilla footprint — plus the paper's headline
//! RAM-reduction and board-fit claims on the real zoo models.

use msf_cnn::exec::Engine;
use msf_cnn::mcu::board_by_name;
use msf_cnn::memory::Arena;
use msf_cnn::model::ModelChain;
use msf_cnn::ops::{ParamGen, Tensor};
use msf_cnn::optimizer::{strategy, Constraint, Constraints, FusionSetting, Planner};
use msf_cnn::zoo;

/// Min-RAM (P1) setting through the planner pipeline.
fn min_ram_setting(m: &ModelChain) -> FusionSetting {
    Planner::for_model(m.clone()).plan().unwrap().setting
}

fn input_for(m: &ModelChain, seed: u64) -> Tensor {
    let s = m.shapes[0];
    Tensor::from_data(
        s.h as usize,
        s.w as usize,
        s.c as usize,
        ParamGen::new(seed).fill(s.elems() as usize, 2.0),
    )
}

#[test]
fn vanilla_measured_equals_predicted_for_all_zoo_models() {
    for name in ["quickstart", "tiny", "lenet", "kws", "mn2-vww5"] {
        let m = zoo::by_name(name).unwrap();
        let vanilla = Planner::for_model(m.clone())
            .strategy(strategy::Vanilla)
            .setting()
            .unwrap();
        let engine = Engine::new(m.clone());
        let mut arena = Arena::unbounded();
        let r = engine.run(&vanilla, &input_for(&m, 1), &mut arena).unwrap();
        assert_eq!(r.peak_ram, m.vanilla_peak_ram(), "{name}");
    }
}

#[test]
fn fused_measured_vs_predicted_relationship() {
    for name in ["quickstart", "tiny", "kws", "mn2-vww5"] {
        let m = zoo::by_name(name).unwrap();
        let engine = Engine::new(m.clone());
        let s = min_ram_setting(&m);
        let mut arena = Arena::unbounded();
        let r = engine.run(&s, &input_for(&m, 2), &mut arena).unwrap();
        // Band-pyramid execution holds >= the analytical tile model…
        assert!(
            r.peak_ram >= s.cost.peak_ram,
            "{name}: measured {} < predicted {}",
            r.peak_ram,
            s.cost.peak_ram
        );
        // …but both crush the vanilla footprint (the point of the paper).
        assert!(r.peak_ram < m.vanilla_peak_ram(), "{name}");
        // And the deviation stays bounded: the executor's band buffers are
        // full-width (W) where the paper's Eq. 11 tiles are t-wide, so the
        // gap scales with W/t (≈6-12x on these small maps). What matters
        // for the reproduction is that both sides track each other within
        // that structural factor rather than diverging arbitrarily.
        let width = m.shapes[0].w as u64;
        assert!(
            r.peak_ram <= s.cost.peak_ram * width.max(8),
            "{name}: measured {} vs predicted {} drifted beyond the band/tile factor",
            r.peak_ram,
            s.cost.peak_ram
        );
    }
}

#[test]
fn paper_headline_50pct_vs_prior_art() {
    // Table 2's claim: msf-CNN ~halves prior art's (single-block fusion)
    // peak RAM on the paper models — here on the analytical encoding.
    for (name, m) in zoo::paper_models() {
        let mut planner = Planner::for_model(m.clone());
        let msf = planner.plan().unwrap().cost().peak_ram as f64;
        let sn = planner
            .plan_with(&strategy::StreamNet, Constraints::none())
            .unwrap()
            .cost()
            .peak_ram as f64;
        assert!(
            msf <= sn * 0.66,
            "{name}: msf {msf} vs streamnet {sn} — expected >=34% cut"
        );
    }
}

#[test]
fn sixteen_kb_board_nearly_fits_mbv2_min_ram() {
    // Paper §8.1: MBV2-w0.35 deployed on the 16 kB SiFive board at
    // 8.56 kB. Our reconstruction lands at ~17 kB — the residual gap vs
    // the paper comes from (a) the reconstructed (not NAS-identical)
    // architecture and (b) f32 pool/dense accumulators where their int8
    // pipeline requantizes in-stream. Pin the reproduction at "within
    // 1.25x of the 16 kB class" and keep the ordering claims exact.
    let m = zoo::mbv2(0.35, 144, 1000);
    let s = min_ram_setting(&m);
    let hifive = board_by_name("hifive1b").unwrap();
    assert!(
        (s.cost.peak_ram as f64) <= hifive.ram_bytes() as f64 * 1.25,
        "min-RAM setting {} B should be in the 16 kB class",
        s.cost.peak_ram
    );
    // And it must be the *smallest* of the three paper models — the reason
    // MBV2 is the one the paper could deploy on the SiFive.
    for (name, other) in zoo::paper_models() {
        if name == "MBV2-w0.35" {
            continue;
        }
        let os = min_ram_setting(&other);
        assert!(s.cost.peak_ram <= os.cost.peak_ram, "{name} smaller than MBV2?");
    }
}

#[test]
fn compiled_pool_reconciles_with_analytic_and_interpreted_peaks() {
    // The compile-once path must tell the same memory story: its
    // watermark (known statically) equals the interpreted engine's
    // measured arena peak, which in turn sits >= the analytic Eq. 5-6
    // encoding for fused settings and == it for vanilla.
    for name in ["quickstart", "tiny", "kws"] {
        let m = zoo::by_name(name).unwrap();
        let engine = Engine::new(m.clone());

        let s = min_ram_setting(&m);
        let compiled = engine.compile(&s);
        assert!(
            compiled.measured_peak() >= s.cost.peak_ram,
            "{name}: compiled watermark {} below analytic {}",
            compiled.measured_peak(),
            s.cost.peak_ram
        );
        assert!(compiled.pool_bytes() >= compiled.measured_peak(), "{name}");
        let mut arena = Arena::unbounded();
        let r = engine.run(&s, &input_for(&m, 6), &mut arena).unwrap();
        assert_eq!(compiled.measured_peak(), r.peak_ram, "{name}: watermark != arena peak");

        // Vanilla: the compiled watermark is the Eq. 5 closed form.
        let vanilla = Planner::for_model(m.clone())
            .strategy(strategy::Vanilla)
            .setting()
            .unwrap();
        let cv = engine.compile(&vanilla);
        assert_eq!(cv.measured_peak(), m.vanilla_peak_ram(), "{name}");
    }
}

#[test]
fn oom_on_budget_that_is_too_small() {
    let m = zoo::quickstart();
    let engine = Engine::new(m.clone());
    let s = min_ram_setting(&m);
    // A budget below the *measured* requirement must OOM...
    let mut tiny = Arena::with_budget(64);
    assert!(engine.run(&s, &input_for(&m, 3), &mut tiny).is_err());
    // ...and a generous budget must succeed.
    let mut big = Arena::with_budget(m.vanilla_peak_ram() * 4);
    assert!(engine.run(&s, &input_for(&m, 3), &mut big).is_ok());
}

#[test]
fn p2_settings_fit_their_declared_budget_when_executed() {
    // For every P2 budget, the *analytical* peak respects the budget by
    // construction; verify execution stays within a banded factor (the
    // band-vs-tile gap) and never exceeds vanilla.
    let m = zoo::quickstart();
    let engine = Engine::new(m.clone());
    let mut planner = Planner::for_model(m.clone());
    for p_max in [4_000u64, 6_000, 12_000] {
        let c = Constraints::none().with(Constraint::Ram(p_max));
        if let Ok(plan) = planner.plan_with(&strategy::P2, c) {
            let s = plan.setting;
            assert!(s.cost.peak_ram <= p_max);
            let mut arena = Arena::unbounded();
            let r = engine.run(&s, &input_for(&m, 4), &mut arena).unwrap();
            assert!(r.peak_ram <= m.vanilla_peak_ram());
        }
    }
}
