//! Numeric-soundness verifier integration: the small-model zoo × every
//! strategy proves its int8 twins free of accumulator overflow with
//! well-formed calibration, each injected numeric defect class is
//! pinned to its finding (step index + buffer name), a saturation-risk
//! warning never blocks registry deploy, the abstract value ranges
//! bound what the concrete int8 kernels actually produce, and the
//! defect-class taxonomy round-trips exhaustively.

use std::path::PathBuf;

use msf_cnn::analysis::{self, ranges, DefectClass, NumericInput, Severity};
use msf_cnn::coordinator::{MultiModelServer, PlanRegistry};
use msf_cnn::model::{Layer, ModelChain, TensorShape};
use msf_cnn::ops::{LayerParams, ParamGen, Tensor};
use msf_cnn::optimizer::{strategy, Constraints, Planner, PlanStrategy};
use msf_cnn::qexec::{calibrate_default, QCompiledPlan};
use msf_cnn::zoo;

const STRATEGIES: [(&str, &dyn PlanStrategy); 5] = [
    ("p1", &strategy::P1),
    ("p2", &strategy::P2),
    ("vanilla", &strategy::Vanilla),
    ("head-fusion", &strategy::HeadFusion),
    ("streamnet", &strategy::StreamNet),
];

/// The models small enough to calibrate (one f32 inference each) inside
/// a debug-build test; `msfcnn verify --zoo` covers the full zoo in
/// release as the CI `make analysis` gate.
const SMALL_MODELS: [&str; 4] = ["quickstart", "tiny", "lenet", "kws"];

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("msfcnn-an-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn params_for(m: &ModelChain) -> Vec<LayerParams> {
    m.layers.iter().enumerate().map(|(i, l)| LayerParams::for_layer(l, i)).collect()
}

fn calibrated_spec(m: &ModelChain) -> msf_cnn::ops::QuantSpec {
    calibrate_default(m, &params_for(m))
}

// ------------------------------------------------------- clean int8 matrix

/// Every plannable `(small model, strategy)` pair's int8 twin verifies
/// with zero findings: no accumulator can overflow, every calibration
/// parameter is well-formed, no requant epilogue is at saturation risk,
/// and no store is dead — the numeric pass has no false positives on
/// honestly calibrated plans.
#[test]
fn small_zoo_int8_matrix_verifies_numerically_clean() {
    let mut verified = 0usize;
    for name in SMALL_MODELS {
        let m = zoo::by_name(name).unwrap();
        let spec = calibrated_spec(&m);
        let mut planner = Planner::for_model(m.clone());
        for (sname, s) in STRATEGIES {
            let plan = match planner.plan_with(s, Constraints::none()) {
                Ok(p) => p,
                Err(_) => continue, // infeasible pair: nothing to verify
            };
            let qplan = plan.with_quant(spec.clone());
            let report = analysis::verify_plan(&qplan, &m);
            assert!(report.is_clean(), "{name} x {sname} int8:\n{}", report.render());
            assert!(report.steps_checked > 0, "{name} x {sname}: no steps walked");
            verified += 1;
        }
    }
    assert!(verified >= 2 * SMALL_MODELS.len(), "matrix mostly infeasible: {verified}");
}

// -------------------------------------------------------- defect injection

/// A model whose dense reduction is long enough that the worst-case
/// `|x-zx|·|w-zw|` sum provably exceeds i32 — the overflow finding names
/// the step and the buffer the accumulator feeds.
#[test]
fn genuine_accumulator_overflow_is_flagged_with_location() {
    // 200000 taps x |dev| <= 255*255 could reach ~1.3e10 >> i32::MAX;
    // even the most favorable zero points leave 200000*128*128 ~ 3.3e9.
    let m = ModelChain::new(
        "ovf",
        TensorShape::new(1, 1, 200_000),
        vec![Layer::dense("fc", 200_000, 8)],
    );
    let spec = calibrated_spec(&m);
    let plan = Planner::for_model(m.clone())
        .plan_with(&strategy::Vanilla, Constraints::none())
        .unwrap()
        .with_quant(spec);
    let report = analysis::verify_plan(&plan, &m);
    assert!(report.has_errors(), "overflow not flagged:\n{}", report.render());
    let f = report
        .findings
        .iter()
        .find(|f| f.class == DefectClass::AccumulatorOverflow)
        .unwrap_or_else(|| panic!("no overflow finding:\n{}", report.render()));
    assert_eq!(f.severity, Severity::Error);
    assert!(f.step.is_some(), "overflow finding names no step: {}", f.render());
    assert!(!f.buffer.is_empty(), "overflow finding names no buffer: {}", f.render());
}

/// Calibration corruptions of an in-memory numeric view land in their
/// own classes: a collapsed scale is `degenerate-scale`, an impossible
/// zero point is `zero-point-range`, both located at the unit's step.
#[test]
fn corrupted_calibration_is_flagged_by_class() {
    let m = zoo::by_name("quickstart").unwrap();
    let spec = calibrated_spec(&m);
    let setting = Planner::for_model(m.clone()).setting().unwrap();
    let q = QCompiledPlan::compile(m, setting, spec);
    let good = NumericInput::from_qcompiled(&q);
    assert!(ranges::verify_ranges(&good).is_clean());

    let mut input = good.clone();
    input.steps[0].units[0].x_qp.scale = 0.0;
    let report = ranges::verify_ranges(&input);
    let f = report
        .findings
        .iter()
        .find(|f| f.class == DefectClass::DegenerateScale)
        .unwrap_or_else(|| panic!("no degenerate-scale finding:\n{}", report.render()));
    assert_eq!(f.step, Some(input.steps[0].index));

    let mut input = good.clone();
    if let Some(w) = input.steps[0].units[0].w_qp.as_mut() {
        w.zero_point = 300;
    }
    let report = ranges::verify_ranges(&input);
    assert!(
        report.findings.iter().any(|f| f.class == DefectClass::ZeroPointRange),
        "no zero-point-range finding:\n{}",
        report.render()
    );
}

/// A requant scale collapsed by three orders of magnitude (still legal:
/// positive, parseable, non-degenerate) puts the epilogue at saturation
/// risk — flagged as a warning with the estimated clipped fraction, and
/// never as a deploy-blocking error. The corruption survives the JSON
/// round trip, so `verify_plan_file` catches it on disk too.
#[test]
fn saturating_requant_scale_warns_without_blocking() {
    let dir = tmp_dir("satwarn");
    let m = zoo::by_name("quickstart").unwrap();
    let mut spec = calibrated_spec(&m);
    // Tensor v1 is the first Relu6 conv's output: the worst case there
    // is certain ([0, 6]), so the shrunken representable range clips an
    // estimated ~99.9% of it.
    spec.tensors[1].scale /= 1000.0;
    let plan = Planner::for_model(m.clone()).plan().unwrap().with_quant(spec);

    let report = analysis::verify_plan(&plan, &m);
    assert!(!report.has_errors(), "warning escalated to error:\n{}", report.render());
    assert!(report.warn_count() >= 1, "no saturation warning:\n{}", report.render());
    for f in report.findings.iter().filter(|f| f.severity == Severity::Warn) {
        assert_eq!(f.class, DefectClass::SaturationRisk, "{}", f.render());
        assert!(f.detail.contains('%'), "no clipped fraction estimate: {}", f.render());
    }

    let path = dir.join("quickstart.plan.json");
    plan.save(&path).unwrap();
    let (_, from_disk) = analysis::verify_plan_file(&path).unwrap();
    assert!(!from_disk.has_errors(), "{}", from_disk.render());
    assert!(
        from_disk.findings.iter().any(|f| f.class == DefectClass::SaturationRisk),
        "corruption lost in the JSON round trip:\n{}",
        from_disk.render()
    );
    let _ = std::fs::remove_dir_all(&dir);
}

// ------------------------------------------------------ deploy-time gates

/// Registry sync deploys a plan whose only findings are warnings: the
/// verdict carries them (`!is_clean()` but `is_deployable()`), nothing
/// lands in `ScanReport::errors`, and the model serves.
#[test]
fn registry_sync_deploys_warn_only_plans() {
    let dir = tmp_dir("warndeploy");
    let m = zoo::by_name("quickstart").unwrap();
    let mut spec = calibrated_spec(&m);
    spec.tensors[1].scale /= 1000.0;
    let plan = Planner::for_model(m.clone()).plan().unwrap().with_quant(spec);
    plan.save(dir.join("quickstart.plan.json")).unwrap();

    let mut registry = PlanRegistry::open(&dir).unwrap();
    let server = MultiModelServer::new();
    let handle = server.handle();
    let report = registry.sync(&handle).unwrap();

    assert_eq!(report.added, vec!["quickstart".to_string()], "{report:?}");
    assert!(report.errors.is_empty(), "warning blocked deploy: {report:?}");
    assert_eq!(report.verdicts.len(), 1);
    let v = &report.verdicts[0];
    assert!(!v.is_clean(), "warnings missing from the verdict: {v:?}");
    assert!(v.is_deployable(), "{v:?}");
    assert!(
        v.findings.iter().any(|f| f.contains("[warn:saturation-risk]")),
        "verdict does not render the warning distinctly: {v:?}"
    );
    assert!(handle.model_ids().contains(&"quickstart".to_string()), "model not serving");

    drop(handle);
    server.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

// --------------------------------------------------- range/kernel parity

/// The abstract interpretation is sound against the concrete kernels:
/// dequantized logits from adversarial inputs stay inside the final
/// unit's proven real-value bounds intersected with its representable
/// range (one quantization step of slack for rounding).
#[test]
fn abstract_ranges_bound_measured_kernel_extrema() {
    for name in ["quickstart", "tiny", "kws"] {
        let m = zoo::by_name(name).unwrap();
        let spec = calibrated_spec(&m);
        let setting = Planner::for_model(m.clone()).setting().unwrap();
        let q = QCompiledPlan::compile(m.clone(), setting, spec);

        let numerics = NumericInput::from_qcompiled(&q);
        let last = numerics
            .steps
            .iter()
            .flat_map(|s| s.units.iter())
            .max_by_key(|u| u.layer)
            .expect("a final unit");
        let (a_lo, a_hi) = ranges::unit_real_bounds(last);
        let (r_lo, r_hi) = last.out_qp.representable();
        let slack = last.out_qp.scale as f64;
        let lo = a_lo.max(r_lo as f64) - slack;
        let hi = a_hi.min(r_hi as f64) + slack;

        let s = m.shapes[0];
        let n = s.elems() as usize;
        let mut pool = q.make_pool();
        let mut out = vec![0.0f32; q.output_len()];
        let mut adversarial: Vec<Vec<f32>> = vec![
            vec![1e6; n],
            vec![-1e6; n],
            (0..n).map(|i| if i % 2 == 0 { 1e6 } else { -1e6 }).collect(),
        ];
        for seed in [1u64, 7, 17, 42] {
            adversarial.push(ParamGen::new(seed).fill(n, 100.0));
        }
        for data in adversarial {
            let x = Tensor::from_data(s.h as usize, s.w as usize, s.c as usize, data);
            q.run_into(x.as_map(), &mut pool, &mut out);
            for &y in &out {
                assert!(
                    (y as f64) >= lo && (y as f64) <= hi,
                    "{name}: logit {y} escapes proven range [{lo}, {hi}]"
                );
            }
        }
    }
}

// ----------------------------------------------------- taxonomy round-trip

/// Every defect class round-trips through its stable name, the names
/// are unique (they key JSON exports and grep-able diagnostics), and
/// unknown names stay unknown.
#[test]
fn defect_class_names_round_trip_exhaustively() {
    assert_eq!(DefectClass::ALL.len(), 15);
    let mut seen = std::collections::BTreeSet::new();
    for c in DefectClass::ALL {
        let name = c.name();
        assert!(seen.insert(name), "duplicate defect-class name '{name}'");
        assert_eq!(DefectClass::from_name(name), Some(c), "'{name}' does not round-trip");
    }
    assert_eq!(DefectClass::from_name("made-up-class"), None);
    assert_eq!(DefectClass::from_name(""), None);
    assert_eq!(Severity::Error.name(), "error");
    assert_eq!(Severity::Warn.name(), "warn");
}

// ------------------------------------------------------ hot-path parity

/// Running the numeric pass changes nothing at runtime: warm int8 runs
/// stay allocation-free and bit-identical after `verify_ranges` has
/// walked the plan's numeric view.
#[test]
fn numeric_pass_keeps_quantized_hot_path_allocation_free_and_bit_identical() {
    let m = zoo::by_name("tiny").unwrap();
    let spec = calibrated_spec(&m);
    let setting = Planner::for_model(m.clone()).setting().unwrap();
    let q = QCompiledPlan::compile(m.clone(), setting, spec);
    assert!(ranges::verify_ranges(&NumericInput::from_qcompiled(&q)).is_clean());

    let s = m.shapes[0];
    let x = Tensor::from_data(
        s.h as usize,
        s.w as usize,
        s.c as usize,
        ParamGen::new(17).fill(s.elems() as usize, 2.0),
    );
    let mut pool = q.make_pool();
    let allocs0 = pool.storage_allocs();
    let mut out_a = vec![0.0f32; q.output_len()];
    let mut out_b = vec![0.0f32; q.output_len()];
    q.run_into(x.as_map(), &mut pool, &mut out_a);
    assert!(ranges::verify_ranges(&NumericInput::from_qcompiled(&q)).is_clean());
    q.run_into(x.as_map(), &mut pool, &mut out_b);
    assert_eq!(out_a, out_b, "warm rerun diverged around the numeric pass");
    assert_eq!(pool.storage_allocs(), allocs0, "hot path allocated");
}
