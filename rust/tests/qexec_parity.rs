//! Int8-vs-f32 parity for the quantized compiled executor
//! (`qexec::QCompiledPlan`) against its oracle, the interpreted f32
//! `exec::Engine`: logits within quantization tolerance, identical MAC
//! counts, and — the PR's RAM contract — a **measured** int8 pool peak
//! exactly equal to the analytic Eq. 5/6 peak (the interpreted arena
//! high-water mark; the Eq. 5 closed form for vanilla settings). The
//! warm hot path is also pinned allocation-free.

use msf_cnn::exec::Engine;
use msf_cnn::memory::Arena;
use msf_cnn::model::ModelChain;
use msf_cnn::ops::{LayerParams, ParamGen, QuantSpec, Tensor};
use msf_cnn::optimizer::{strategy, Constraints, FusionSetting, Plan, Planner, PlanStrategy};
use msf_cnn::qexec::{calibrate_default, QCompiledPlan};
use msf_cnn::zoo;

fn strategies() -> [(&'static str, &'static dyn PlanStrategy); 5] {
    [
        ("p1", &strategy::P1),
        ("p2", &strategy::P2),
        ("vanilla", &strategy::Vanilla),
        ("head-fusion", &strategy::HeadFusion),
        ("streamnet", &strategy::StreamNet),
    ]
}

fn input_for(m: &ModelChain, seed: u64) -> Tensor {
    let s = m.shapes[0];
    Tensor::from_data(
        s.h as usize,
        s.w as usize,
        s.c as usize,
        ParamGen::new(seed).fill(s.elems() as usize, 2.0),
    )
}

fn params_for(m: &ModelChain) -> Vec<LayerParams> {
    m.layers.iter().enumerate().map(|(i, l)| LayerParams::for_layer(l, i)).collect()
}

/// Int8 compiled vs interpreted f32 on one setting: logits within
/// `10·scale + slack`, equal MACs, and the measured int8 pool peak equal
/// to the interpreted arena peak (both are the Eq. 5/6 accounting).
fn assert_quant_parity(
    m: &ModelChain,
    setting: &FusionSetting,
    spec: &QuantSpec,
    x: &Tensor,
    tag: &str,
    slack: f32,
) {
    let engine = Engine::new(m.clone());
    let mut arena = Arena::unbounded();
    let interp = engine.run(setting, x, &mut arena).unwrap();

    let q = QCompiledPlan::compile(m.clone(), setting.clone(), spec.clone());
    let mut pool = q.make_pool();
    let mut out = vec![0.0f32; q.output_len()];
    let macs = q.run_into(x.as_map(), &mut pool, &mut out);

    assert_eq!(macs, interp.macs, "{tag}: MAC counts diverged");
    let tol = 10.0 * q.logits_qp().scale + slack;
    for (i, (a, b)) in out.iter().zip(&interp.output).enumerate() {
        assert!(
            (a - b).abs() <= tol,
            "{tag}: logit {i}: int8 {a} vs f32 {b} (tol {tol})"
        );
    }
    assert_eq!(
        q.measured_peak(),
        interp.peak_ram,
        "{tag}: int8 pool watermark != interpreted arena peak"
    );
}

#[test]
fn small_zoo_times_all_strategies_within_quant_tolerance() {
    for name in ["quickstart", "tiny", "lenet", "kws"] {
        let m = zoo::by_name(name).unwrap();
        let spec = calibrate_default(&m, &params_for(&m));
        let x = input_for(&m, 17);
        let mut planner = Planner::for_model(m.clone());
        for (sname, s) in strategies() {
            let setting = planner.plan_with(s, Constraints::none()).unwrap().setting;
            assert_quant_parity(&m, &setting, &spec, &x, &format!("{name}/{sname}"), 0.15);
        }
    }
}

#[test]
fn paper_model_parity_on_fused_strategies() {
    // The residual backbone; the deeper chain accumulates more
    // requantization error, hence the wider slack (same envelope the
    // f32 compiled-parity suite uses for model selection).
    let m = zoo::mcunet_vww5();
    let spec = calibrate_default(&m, &params_for(&m));
    let x = input_for(&m, 23);
    let mut planner = Planner::for_model(m.clone());
    for (sname, s) in [
        ("p1", &strategy::P1 as &dyn PlanStrategy),
        ("streamnet", &strategy::StreamNet),
    ] {
        let setting = planner.plan_with(s, Constraints::none()).unwrap().setting;
        assert_quant_parity(&m, &setting, &spec, &x, &format!("mn2-vww5/{sname}"), 0.25);
    }
}

#[test]
fn vanilla_int8_pool_peak_equals_eq5_closed_form() {
    // For the vanilla setting the Eq. 5 peak has a closed form; the
    // int8 pool must *measure* exactly that, not a scaled proxy.
    for name in ["quickstart", "tiny", "lenet", "kws"] {
        let m = zoo::by_name(name).unwrap();
        let spec = calibrate_default(&m, &params_for(&m));
        let vanilla = Planner::for_model(m.clone())
            .plan_with(&strategy::Vanilla, Constraints::none())
            .unwrap()
            .setting;
        let q = QCompiledPlan::compile(m.clone(), vanilla, spec);
        assert_eq!(q.measured_peak(), m.vanilla_peak_ram(), "{name}");
    }
}

#[test]
fn warm_hot_path_performs_zero_pool_allocations() {
    let m = zoo::kws_cnn();
    let spec = calibrate_default(&m, &params_for(&m));
    let setting = Planner::for_model(m.clone()).setting().unwrap();
    let q = QCompiledPlan::compile(m.clone(), setting, spec);

    let mut pool = q.make_pool();
    let allocs = pool.storage_allocs();
    let ptr = pool.storage_ptr();
    let bytes = pool.bytes();

    let x = input_for(&m, 7);
    let mut out = vec![0.0f32; q.output_len()];
    q.run_into(x.as_map(), &mut pool, &mut out);
    let first = out.clone();
    for _ in 0..50 {
        q.run_into(x.as_map(), &mut pool, &mut out);
        assert_eq!(out, first, "warm rerun diverged");
    }
    // Pinned: the warm path never grows, reallocates, or re-creates the
    // pool's storage — same allocation count, same base pointer, same
    // byte size as right after `make_pool`.
    assert_eq!(pool.storage_allocs(), allocs, "hot path allocated");
    assert_eq!(pool.storage_ptr(), ptr, "pool storage reallocated");
    assert_eq!(pool.bytes(), bytes, "pool storage resized");
}

#[test]
fn serialized_quant_plan_serves_identically() {
    // Save -> load -> compile must reproduce the int8 execution
    // bit-for-bit: the QuantSpec round-trips exactly through plan JSON.
    let m = zoo::tiny_cnn();
    let spec = calibrate_default(&m, &params_for(&m));
    let plan = Planner::for_model(m.clone()).plan().unwrap().with_quant(spec.clone());
    let path = std::env::temp_dir().join("msfcnn-qexec-parity.plan.json");
    plan.save(&path).unwrap();
    let loaded = Plan::load(&path).unwrap();
    let _ = std::fs::remove_file(&path);
    let loaded_spec = loaded.quant.clone().expect("quant spec survives the round trip");
    assert_eq!(loaded_spec, spec);

    let q1 = QCompiledPlan::compile(m.clone(), plan.setting.clone(), spec);
    let q2 = QCompiledPlan::compile(m.clone(), loaded.setting.clone(), loaded_spec);
    let x = input_for(&m, 41);
    let (mut p1, mut p2) = (q1.make_pool(), q2.make_pool());
    let mut o1 = vec![0i8; q1.output_len()];
    let mut o2 = vec![0i8; q2.output_len()];
    q1.run_into_i8(x.as_map(), &mut p1, &mut o1);
    q2.run_into_i8(x.as_map(), &mut p2, &mut o2);
    assert_eq!(o1, o2, "round-tripped plan produced different i8 logits");
}

#[test]
#[ignore = "full zoo x strategy sweep (minutes); run with --ignored"]
fn full_zoo_times_all_strategies_within_quant_tolerance() {
    for name in zoo::MODEL_NAMES {
        let m = zoo::by_name(name).unwrap();
        let spec = calibrate_default(&m, &params_for(&m));
        let x = input_for(&m, 17);
        let mut planner = Planner::for_model(m.clone());
        for (sname, s) in strategies() {
            let Ok(plan) = planner.plan_with(s, Constraints::none()) else {
                continue; // infeasible pairs are covered by `verify --zoo`
            };
            assert_quant_parity(&m, &plan.setting, &spec, &x, &format!("{name}/{sname}"), 0.25);
        }
    }
}
