//! Observability integration: the profiled hot path is bit-identical to
//! the unprofiled one (the PR's parity acceptance criterion), profiled
//! runs stay allocation-free, control-plane trace events arrive in
//! lifecycle order, and per-model metrics survive a hot swap.

use msf_cnn::coordinator::{ModelSpec, MultiModelServer};
use msf_cnn::exec::CompiledPlan;
use msf_cnn::model::ModelChain;
use msf_cnn::obs::{profile_plan, NoProfiler, StepRecorder, TraceEvent, TraceLog};
use msf_cnn::ops::{ParamGen, Tensor};
use msf_cnn::optimizer::Planner;
use msf_cnn::zoo;

fn compiled_for(model: ModelChain) -> CompiledPlan {
    let setting = Planner::for_model(model.clone()).setting().expect("min-RAM plan");
    CompiledPlan::compile(model, setting)
}

fn input_for(compiled: &CompiledPlan, seed: u64) -> Tensor {
    let s = compiled.model().shapes[0];
    Tensor::from_data(
        s.h as usize,
        s.w as usize,
        s.c as usize,
        ParamGen::new(seed).fill(s.elems() as usize, 2.0),
    )
}

// ------------------------------------------------------------------ parity

/// `run_profiled` with the no-op profiler must be *exactly* `run_into`:
/// bit-identical logits, identical MAC counts, and an unchanged pool
/// allocation counter — the zero-cost-when-disabled guarantee.
#[test]
fn noop_profiler_is_bit_identical_and_allocation_free() {
    for model in [zoo::quickstart(), zoo::kws_cnn(), zoo::tiny_cnn()] {
        let name = model.name.clone();
        let compiled = compiled_for(model);
        let x = input_for(&compiled, 11);

        let mut pool_a = compiled.make_pool();
        let mut out_a = vec![0.0f32; compiled.output_len()];
        let macs_a = compiled.run_into(x.as_map(), &mut pool_a, &mut out_a);

        let mut pool_b = compiled.make_pool();
        let mut out_b = vec![0.0f32; compiled.output_len()];
        let macs_b = compiled.run_profiled(x.as_map(), &mut pool_b, &mut out_b, &mut NoProfiler);

        assert_eq!(macs_a, macs_b, "{name}: MACs diverge under NoProfiler");
        assert_eq!(out_a, out_b, "{name}: logits diverge under NoProfiler");

        // Warm re-runs never allocate or move the pool storage.
        let allocs = pool_b.storage_allocs();
        let ptr = pool_b.storage_ptr();
        for _ in 0..3 {
            compiled.run_profiled(x.as_map(), &mut pool_b, &mut out_b, &mut NoProfiler);
        }
        assert_eq!(pool_b.storage_allocs(), allocs, "{name}: warm profiled runs allocated");
        assert_eq!(pool_b.storage_ptr(), ptr, "{name}: pool storage moved");
        assert_eq!(out_a, out_b, "{name}: warm profiled rerun diverged");
    }
}

/// The measuring recorder must not perturb numerics either — only time
/// is observed, never data.
#[test]
fn recording_profiler_preserves_numerics_and_counts_every_step() {
    let compiled = compiled_for(zoo::kws_cnn());
    let x = input_for(&compiled, 29);

    let mut pool = compiled.make_pool();
    let mut out_plain = vec![0.0f32; compiled.output_len()];
    let macs_plain = compiled.run_into(x.as_map(), &mut pool, &mut out_plain);

    let mut rec = StepRecorder::new(compiled.num_steps());
    let mut out_rec = vec![0.0f32; compiled.output_len()];
    let macs_rec = compiled.run_profiled(x.as_map(), &mut pool, &mut out_rec, &mut rec);

    assert_eq!(macs_plain, macs_rec);
    assert_eq!(out_plain, out_rec);
    assert_eq!(rec.runs(), 1);
    for i in 0..compiled.num_steps() {
        assert_eq!(rec.samples_us(i).len(), 1, "step {i} missed a sample");
    }

    // The aggregated attribution accounts for every MAC of the run.
    let profile = profile_plan(&compiled, &x, 4);
    assert_eq!(profile.total_macs(), macs_plain);
    assert_eq!(profile.steps.len(), compiled.num_steps());
}

// ------------------------------------------------------------------- trace

fn engine_spec(id: &str, model: ModelChain) -> ModelSpec {
    let setting = Planner::for_model(model.clone()).setting().expect("min-RAM plan");
    ModelSpec::engine(id, model, setting)
}

/// Deploy → swap → retire → shutdown arrive at the sink in lifecycle
/// order, with executor drains attributed to their model.
#[test]
fn trace_events_follow_the_control_plane_lifecycle() {
    let server = MultiModelServer::new();
    let handle = server.handle();
    let log = TraceLog::new();
    handle.set_trace_sink(log.clone());

    let tiny = zoo::tiny_cnn();
    handle.deploy(engine_spec("tiny", tiny.clone())).unwrap();
    handle.infer("tiny", ParamGen::new(3).fill(tiny.shapes[0].elems() as usize, 2.0)).unwrap();
    handle.swap(engine_spec("tiny", tiny.clone())).unwrap();
    handle.retire("tiny").unwrap();
    drop(handle);
    server.shutdown();

    let events = log.events();
    let kinds: Vec<&'static str> = events
        .iter()
        .map(|e| match e {
            TraceEvent::Deploy { .. } => "deploy",
            TraceEvent::Swap { .. } => "swap",
            TraceEvent::Retire { .. } => "retire",
            TraceEvent::Drain { .. } => "drain",
            TraceEvent::Shutdown => "shutdown",
            TraceEvent::RegistrySync { .. } => "sync",
        })
        .collect();
    let pos = |k: &str| {
        kinds
            .iter()
            .position(|&x| x == k)
            .unwrap_or_else(|| panic!("no {k} event in {kinds:?}"))
    };
    assert!(pos("deploy") < pos("swap"), "{kinds:?}");
    assert!(pos("swap") < pos("retire"), "{kinds:?}");
    assert!(pos("retire") < pos("shutdown"), "{kinds:?}");
    // Both the swapped-out and the retired executor drained.
    let drains = events
        .iter()
        .filter(|e| matches!(e, TraceEvent::Drain { .. }))
        .count();
    assert!(drains >= 2, "expected both executors to drain, got {drains} in {kinds:?}");
    for e in &events {
        if let Some(id) = e.model_id() {
            assert_eq!(id, "tiny");
        }
    }
}

// ----------------------------------------------------------------- metrics

/// A hot swap replaces the backend, not the model's telemetry: counts
/// keep accumulating across the generation change.
#[test]
fn metrics_survive_a_hot_swap() {
    let tiny = zoo::tiny_cnn();
    let server = MultiModelServer::start(vec![engine_spec("tiny", tiny.clone())]).unwrap();
    let handle = server.handle();
    let input = || ParamGen::new(5).fill(tiny.shapes[0].elems() as usize, 2.0);

    for _ in 0..4 {
        handle.infer("tiny", input()).unwrap();
    }
    let before = handle.metrics().model("tiny").map(|m| m.completed()).unwrap_or(0);
    assert_eq!(before, 4);

    handle.swap(engine_spec("tiny", tiny.clone())).unwrap();
    for _ in 0..3 {
        handle.infer("tiny", input()).unwrap();
    }

    let metrics = handle.metrics();
    let m = metrics.model("tiny").expect("metrics survive the swap");
    assert_eq!(m.completed(), 7, "completions reset across hot swap");
    assert_eq!(m.histogram().count(), 7, "histogram reset across hot swap");
    let stats = m.stats().expect("stats present");
    assert_eq!(stats.count, 7);
    assert!(stats.p50_us <= stats.p95_us && stats.p95_us <= stats.p99_us);
    assert!(m.exec_mean_us().unwrap_or(0.0) > 0.0, "exec split missing after swap");

    drop(handle);
    server.shutdown();
}
