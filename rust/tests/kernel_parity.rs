//! Randomized shape/stride/padding parity fuzzing: the engineered
//! interior/halo kernels against the retained naive loop nests in
//! `ops::reference`. The f32 pairs must be **bit-identical** (the
//! compiled path is pinned bit-identical to the interpreted engine, so
//! the restructure may not change a single ulp); the int8 pairs must be
//! **exactly identical** (i32 accumulation is associative, so the
//! blocked/unrolled twins must land on the same integers).
//!
//! Deterministic xorshift-driven sweeps plus an explicit degenerate
//! list: kernels larger than the input, padding >= kernel, exact-fit
//! 1x1 outputs (empty interior), stride > kernel, and channel counts
//! crossing the int8 blocking width.

use msf_cnn::model::Activation;
use msf_cnn::ops::reference as naive;
use msf_cnn::ops::{
    avg_pool2d_into, conv2d_into, dense_into, dwconv2d_into, max_pool2d_into, qavg_pool2d_into,
    qconv2d_into, qdense_into, qdwconv2d_into, qmax_pool2d_into, MapRef, ParamGen, QLayerParams,
    QMapRef, QParams,
};

/// Tiny deterministic xorshift64 for shape draws (no `rand` in-tree).
struct Rng(u64);

impl Rng {
    fn new(seed: u64) -> Self {
        Self(seed.wrapping_mul(0x9E3779B97F4A7C15) | 1)
    }

    fn next(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        self.0 = x;
        x
    }

    /// Uniform in `[lo, hi]` inclusive.
    fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + (self.next() as usize) % (hi - lo + 1)
    }

    fn act(&mut self) -> Activation {
        match self.range(0, 2) {
            0 => Activation::None,
            1 => Activation::Relu,
            _ => Activation::Relu6,
        }
    }

    fn i8s(&mut self, n: usize) -> Vec<i8> {
        (0..n).map(|_| self.next() as i8).collect()
    }
}

/// A conv-shaped case: `(h, w, c, k, stride, padding, cout)`. The draw
/// keeps `h + 2p >= k` and `w + 2p >= k` so the output is non-empty;
/// everything else (padding >= k, stride > k, 1x1 outputs, kernels
/// wider than the input) is in range.
fn conv_case(rng: &mut Rng) -> (usize, usize, usize, usize, usize, usize, usize) {
    loop {
        let k = rng.range(1, 5);
        let h = rng.range(1, 9);
        let w = rng.range(1, 9);
        let s = rng.range(1, 4);
        let p = rng.range(0, k + 1);
        if h + 2 * p < k || w + 2 * p < k {
            continue;
        }
        let c = rng.range(1, 8);
        let cout = rng.range(1, 12);
        return (h, w, c, k, s, p, cout);
    }
}

fn conv_out(h: usize, w: usize, k: usize, s: usize, p: usize) -> (usize, usize) {
    ((h + 2 * p - k) / s + 1, (w + 2 * p - k) / s + 1)
}

/// Degenerate conv-shaped cases the sweep might miss, by construction:
/// kernel wider than the input, padding >= kernel, exact-fit 1x1 output
/// (no interior at all), stride larger than the kernel.
const DEGENERATE: &[(usize, usize, usize, usize, usize, usize, usize)] = &[
    (2, 2, 3, 5, 1, 4, 7),  // k > input, heavy padding
    (4, 4, 2, 3, 1, 3, 5),  // padding >= k
    (3, 3, 4, 3, 1, 0, 66), // exact-fit 1x1 output, cout crosses QBLOCK
    (7, 7, 3, 2, 3, 1, 4),  // stride > k
    (1, 9, 2, 1, 1, 0, 3),  // single-row map, 1x1 kernel
    (9, 1, 2, 3, 2, 2, 130), // single-column map, cout > 2*QBLOCK
];

fn f32_conv_parity(case: (usize, usize, usize, usize, usize, usize, usize), seed: u64) {
    let (h, w, c, k, s, p, cout) = case;
    let mut gen = ParamGen::new(seed);
    let mut rng = Rng::new(seed ^ 0xC0FFEE);
    let act = rng.act();
    let xf = gen.fill(h * w * c, 2.0);
    let x = MapRef::new(h, w, c, &xf);
    let (ho, wo) = conv_out(h, w, k, s, p);

    let wt = gen.fill(k * k * c * cout, 0.8);
    let bias = gen.fill(cout, 0.2);
    let mut a = vec![7.75f32; ho * wo * cout];
    let mut b = vec![-3.25f32; ho * wo * cout];
    naive::conv2d_naive(x, &wt, &bias, k, s, p, cout, act, &mut a);
    conv2d_into(x, &wt, &bias, k, s, p, cout, act, &mut b);
    assert_eq!(a, b, "conv2d {case:?} act {act:?}");

    let dwt = gen.fill(k * k * c, 0.8);
    let dbias = gen.fill(c, 0.2);
    let mut a = vec![7.75f32; ho * wo * c];
    let mut b = vec![-3.25f32; ho * wo * c];
    naive::dwconv2d_naive(x, &dwt, &dbias, k, s, p, act, &mut a);
    dwconv2d_into(x, &dwt, &dbias, k, s, p, act, &mut b);
    assert_eq!(a, b, "dwconv2d {case:?} act {act:?}");
}

fn int8_conv_parity(case: (usize, usize, usize, usize, usize, usize, usize), seed: u64) {
    let (h, w, c, k, s, p, cout) = case;
    let mut gen = ParamGen::new(seed);
    let mut rng = Rng::new(seed ^ 0xFACADE);
    let act = rng.act();
    let x_qp = QParams::from_range(-3.0, 3.0);
    let out_qp = QParams::from_range(-6.0, 6.0);
    let xq_d = rng.i8s(h * w * c);
    let x = QMapRef::new(h, w, c, &xq_d);
    let (ho, wo) = conv_out(h, w, k, s, p);

    let qp = QLayerParams {
        w_q: rng.i8s(k * k * c * cout),
        w_qp: QParams::from_range(-1.0, 1.0),
        bias: gen.fill(cout, 0.2),
    };
    let mut a = vec![0x55i8; ho * wo * cout];
    let mut b = vec![-0x55i8; ho * wo * cout];
    naive::qconv2d_naive(x, x_qp, &qp, k, s, p, cout, act, out_qp, &mut a);
    qconv2d_into(x, x_qp, &qp, k, s, p, cout, act, out_qp, &mut b);
    assert_eq!(a, b, "qconv2d {case:?} act {act:?}");

    let dqp = QLayerParams {
        w_q: rng.i8s(k * k * c),
        w_qp: QParams::from_range(-1.0, 1.0),
        bias: gen.fill(c, 0.2),
    };
    let mut a = vec![0x55i8; ho * wo * c];
    let mut b = vec![-0x55i8; ho * wo * c];
    naive::qdwconv2d_naive(x, x_qp, &dqp, k, s, p, act, out_qp, &mut a);
    qdwconv2d_into(x, x_qp, &dqp, k, s, p, act, out_qp, &mut b);
    assert_eq!(a, b, "qdwconv2d {case:?} act {act:?}");
}

#[test]
fn fuzz_conv_kernels_f32_bit_identical() {
    for seed in 0..60u64 {
        let mut rng = Rng::new(seed);
        f32_conv_parity(conv_case(&mut rng), seed + 1000);
    }
}

#[test]
fn fuzz_conv_kernels_int8_exact() {
    for seed in 0..60u64 {
        let mut rng = Rng::new(seed ^ 0xABCD);
        let mut case = conv_case(&mut rng);
        // Force some channel counts across the int8 blocking width.
        if seed % 7 == 0 {
            case.6 = 63 + (seed as usize % 5); // 63..=67 straddles QBLOCK=64
        }
        int8_conv_parity(case, seed + 2000);
    }
}

#[test]
fn degenerate_conv_shapes_stay_identical() {
    for (i, &case) in DEGENERATE.iter().enumerate() {
        f32_conv_parity(case, 3000 + i as u64);
        int8_conv_parity(case, 4000 + i as u64);
    }
}

#[test]
fn fuzz_pool_kernels_f32_bit_identical() {
    for seed in 0..40u64 {
        let mut rng = Rng::new(seed ^ 0x9A9A);
        let k = rng.range(1, 4);
        let h = rng.range(k, k + 7);
        let w = rng.range(k, k + 7);
        let s = rng.range(1, k + 2); // stride > k in range
        let c = rng.range(1, 9);
        let mut gen = ParamGen::new(seed + 5000);
        let xf = gen.fill(h * w * c, 2.0);
        let x = MapRef::new(h, w, c, &xf);
        let (ho, wo) = ((h - k) / s + 1, (w - k) / s + 1);
        let mut a = vec![7.75f32; ho * wo * c];
        let mut b = vec![-3.25f32; ho * wo * c];
        naive::avg_pool2d_naive(x, k, s, &mut a);
        avg_pool2d_into(x, k, s, &mut b);
        assert_eq!(a, b, "avg_pool {h}x{w}x{c} k{k} s{s}");
        naive::max_pool2d_naive(x, k, s, &mut a);
        max_pool2d_into(x, k, s, &mut b);
        assert_eq!(a, b, "max_pool {h}x{w}x{c} k{k} s{s}");
    }
}

#[test]
fn fuzz_pool_kernels_int8_exact() {
    for seed in 0..40u64 {
        let mut rng = Rng::new(seed ^ 0x7E7E);
        let k = rng.range(1, 4);
        let h = rng.range(k, k + 7);
        let w = rng.range(k, k + 7);
        let s = rng.range(1, k + 2);
        // Straddle the blocking width on some draws.
        let c = if seed % 5 == 0 { 63 + (seed as usize % 4) } else { rng.range(1, 9) };
        let x_qp = QParams::from_range(-3.0, 3.0);
        let out_qp = QParams::from_range(-4.0, 4.0);
        let xq_d = rng.i8s(h * w * c);
        let x = QMapRef::new(h, w, c, &xq_d);
        let (ho, wo) = ((h - k) / s + 1, (w - k) / s + 1);
        let mut a = vec![0x55i8; ho * wo * c];
        let mut b = vec![-0x55i8; ho * wo * c];
        naive::qavg_pool2d_naive(x, x_qp, k, s, out_qp, &mut a);
        qavg_pool2d_into(x, x_qp, k, s, out_qp, &mut b);
        assert_eq!(a, b, "qavg_pool {h}x{w}x{c} k{k} s{s}");
        naive::qmax_pool2d_naive(x, x_qp, k, s, out_qp, &mut a);
        qmax_pool2d_into(x, x_qp, k, s, out_qp, &mut b);
        assert_eq!(a, b, "qmax_pool {h}x{w}x{c} k{k} s{s}");
    }
}

#[test]
fn fuzz_dense_kernels_stay_identical() {
    for seed in 0..30u64 {
        let mut rng = Rng::new(seed ^ 0x2468);
        let din = rng.range(1, 200);
        // Cross the int8 blocking width on some draws.
        let dout = if seed % 4 == 0 { 60 + (seed as usize % 10) } else { rng.range(1, 40) };
        let mut gen = ParamGen::new(seed + 6000);
        let xf = gen.fill(din, 2.0);
        let wt = gen.fill(din * dout, 0.5);
        let bias = gen.fill(dout, 0.2);
        let mut a = vec![7.75f32; dout];
        let mut b = vec![-3.25f32; dout];
        naive::dense_naive(&xf, &wt, &bias, dout, &mut a);
        dense_into(&xf, &wt, &bias, dout, &mut b);
        assert_eq!(a, b, "dense {din}->{dout}");

        let x_qp = QParams::from_range(-3.0, 3.0);
        let out_qp = QParams::from_range(-8.0, 8.0);
        let xq = rng.i8s(din);
        let qp = QLayerParams {
            w_q: rng.i8s(din * dout),
            w_qp: QParams::from_range(-1.0, 1.0),
            bias,
        };
        let mut a = vec![0x55i8; dout];
        let mut b = vec![-0x55i8; dout];
        naive::qdense_naive(&xq, x_qp, &qp, dout, out_qp, &mut a);
        qdense_into(&xq, x_qp, &qp, dout, out_qp, &mut b);
        assert_eq!(a, b, "qdense {din}->{dout}");
    }
}
