//! Control-plane integration: the full zoo → plan → registry → serve
//! loop, including the ISSUE's acceptance path — a latency-constrained
//! plan deployed from a plan file through [`PlanRegistry`] into a running
//! [`MultiModelServer`], hot-swapped for a different plan, with outputs
//! bit-identical to direct [`InferBackend::run`] before and after.

use std::path::PathBuf;

use msf_cnn::backend::{EngineBackend, InferBackend};
use msf_cnn::coordinator::{ModelSpec, MultiModelServer, PlanRegistry, ServeError};
use msf_cnn::mcu::{board_by_name, estimate_latency_ms};
use msf_cnn::ops::ParamGen;
use msf_cnn::optimizer::strategy::{LatencyAware, Vanilla};
use msf_cnn::optimizer::{Constraint, Plan, Planner};
use msf_cnn::zoo;

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("msfcnn-cp-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn input_for(model_name: &str, seed: u64) -> Vec<f32> {
    let m = zoo::by_name(model_name).unwrap();
    ParamGen::new(seed).fill(m.shapes[0].elems() as usize, 2.0)
}

/// Direct (serverless) execution of a plan on one input.
fn run_direct(plan: &Plan, input: &[f32]) -> Vec<f32> {
    EngineBackend::from_plan(plan).unwrap().run(input).unwrap()
}

#[test]
fn latency_constrained_plan_deploys_and_hot_swaps_bit_identically() {
    let board = board_by_name("nucleo-f767zi").unwrap();
    let model = zoo::quickstart();

    // Plan A: the acceptance pipeline — latency-constrained LatencyAware
    // solve whose recorded estimate is within budget. The budget is set
    // just above the min-RAM setting's own latency, so the solve is
    // constrained but the RAM-optimal (non-vanilla) setting stays
    // feasible.
    let min_ram_ms = {
        let mut p = Planner::for_model(model.clone());
        let s = p.setting().unwrap();
        estimate_latency_ms(&model, &s, board).total_ms
    };
    let budget = min_ram_ms * 1.25;
    let plan_a = Planner::for_model(model.clone())
        .constraint(Constraint::LatencyMs { board, budget })
        .strategy(LatencyAware::default())
        .plan()
        .unwrap();
    let recorded = plan_a.latency.clone().expect("latency provenance");
    assert_eq!(recorded.board, "nucleo-f767zi");
    assert!(recorded.estimate_ms <= budget * (1.0 + 1e-9) + 1e-9);

    // Plan B: a different setting for the same model (vanilla spans).
    let plan_b = Planner::for_model(model.clone()).strategy(Vanilla).plan().unwrap();
    assert_ne!(plan_a.setting.spans, plan_b.setting.spans, "swap must change the plan");

    // Deploy plan A as a *file* through the registry.
    let dir = tmp_dir("accept");
    plan_a.save(dir.join("quickstart.plan.json")).unwrap();
    let mut registry = PlanRegistry::open(&dir).unwrap();
    let server = MultiModelServer::new();
    let handle = server.handle();
    let report = registry.sync(&handle).unwrap();
    assert_eq!(report.added, vec!["quickstart".to_string()]);
    assert_eq!(handle.model_ids(), vec!["quickstart".to_string()]);
    assert_eq!(registry.latest("quickstart").unwrap().version, 1);
    assert_eq!(
        registry.latest("quickstart").unwrap().plan.latency.as_ref().unwrap().board,
        "nucleo-f767zi",
        "the registry entry carries the deploy artifact's latency provenance"
    );

    // Served outputs are bit-identical to direct backend runs of plan A.
    let inputs: Vec<Vec<f32>> = (0..4).map(|i| input_for("quickstart", 40 + i)).collect();
    for x in &inputs {
        assert_eq!(handle.infer("quickstart", x.clone()).unwrap(), run_direct(&plan_a, x));
    }

    // Hot-swap: overwrite the plan file, re-sync, and the same id now
    // serves plan B — again bit-identical to the direct runs.
    plan_b.save(dir.join("quickstart.plan.json")).unwrap();
    let report = registry.sync(&handle).unwrap();
    assert_eq!(report.updated, vec!["quickstart".to_string()]);
    assert_eq!(registry.latest("quickstart").unwrap().version, 2);
    // The old version stays queryable (audit / rollback inspection).
    assert_eq!(registry.get("quickstart", 1).unwrap().plan, plan_a);
    for x in &inputs {
        assert_eq!(handle.infer("quickstart", x.clone()).unwrap(), run_direct(&plan_b, x));
    }

    // Metrics survived the swap: one id, cumulative count across plans.
    let metrics = handle.metrics();
    assert_eq!(metrics.model("quickstart").unwrap().completed(), 2 * inputs.len());

    drop(handle);
    server.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn registry_scan_tracks_new_updated_and_removed_files() {
    let dir = tmp_dir("scan");
    Planner::for_model(zoo::tiny_cnn())
        .plan()
        .unwrap()
        .save(dir.join("tiny.plan.json"))
        .unwrap();

    let mut registry = PlanRegistry::open(&dir).unwrap();
    assert_eq!(registry.scan().unwrap().added, vec!["tiny".to_string()]);
    assert_eq!(registry.model_ids(), vec!["tiny".to_string()]);

    // No change ⇒ empty report.
    assert!(registry.scan().unwrap().is_empty());

    // A new file is picked up…
    Planner::for_model(zoo::kws_cnn())
        .plan()
        .unwrap()
        .save(dir.join("kws.plan.json"))
        .unwrap();
    // …and an update to an existing one bumps its version.
    Planner::for_model(zoo::tiny_cnn())
        .strategy(Vanilla)
        .plan()
        .unwrap()
        .save(dir.join("tiny.plan.json"))
        .unwrap();
    let report = registry.scan().unwrap();
    assert_eq!(report.added, vec!["kws".to_string()]);
    assert_eq!(report.updated, vec!["tiny".to_string()]);
    assert_eq!(registry.latest("tiny").unwrap().version, 2);
    assert_eq!(registry.latest("tiny").unwrap().plan.strategy, "vanilla");
    assert_eq!(registry.get("tiny", 1).unwrap().plan.strategy, "p1-min-ram");

    // Deleting a file removes the model.
    std::fs::remove_file(dir.join("kws.plan.json")).unwrap();
    let report = registry.scan().unwrap();
    assert_eq!(report.removed, vec!["kws".to_string()]);
    assert!(registry.latest("kws").is_none());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn registry_sync_deploys_swaps_and_retires_on_a_live_server() {
    let dir = tmp_dir("sync");
    Planner::for_model(zoo::tiny_cnn())
        .plan()
        .unwrap()
        .save(dir.join("tiny.plan.json"))
        .unwrap();
    Planner::for_model(zoo::kws_cnn())
        .plan()
        .unwrap()
        .save(dir.join("kws.plan.json"))
        .unwrap();

    let mut registry = PlanRegistry::open(&dir).unwrap();
    let server = MultiModelServer::new();
    let handle = server.handle();
    registry.sync(&handle).unwrap();
    assert_eq!(handle.model_ids(), vec!["kws".to_string(), "tiny".to_string()]);
    assert!(handle.infer("tiny", input_for("tiny", 1)).is_ok());
    assert!(handle.infer("kws", input_for("kws", 2)).is_ok());

    // Remove one file: the next sync retires it; the other keeps serving.
    std::fs::remove_file(dir.join("kws.plan.json")).unwrap();
    registry.sync(&handle).unwrap();
    assert_eq!(handle.model_ids(), vec!["tiny".to_string()]);
    let err = handle.submit("kws", input_for("kws", 3)).unwrap_err();
    assert_eq!(err, ServeError::UnknownModel { model_id: "kws".into() });
    assert!(handle.infer("tiny", input_for("tiny", 4)).is_ok());

    drop(handle);
    server.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn swap_drains_queued_requests_without_drops() {
    // A serial (batch_max = 1) executor with a deep queue: stack requests
    // behind it, hot-swap mid-flight, and require every queued request to
    // complete on the old plan — no drops, no ShuttingDown replies.
    let model = zoo::quickstart();
    let plan_fused = Planner::for_model(model.clone()).plan().unwrap();
    let plan_vanilla = Planner::for_model(model.clone()).strategy(Vanilla).plan().unwrap();

    let server = MultiModelServer::new();
    let handle = server.handle();
    handle
        .deploy(ModelSpec::plan("qs", plan_fused.clone()).with_queue(64, 1))
        .unwrap();

    let total = 12usize;
    let inputs: Vec<Vec<f32>> = (0..total).map(|i| input_for("quickstart", i as u64)).collect();
    let mut pendings = Vec::new();
    for x in &inputs {
        pendings.push(handle.submit("qs", x.clone()).unwrap());
    }

    // Swap while the old executor still has most of the queue buffered.
    handle
        .swap(ModelSpec::plan("qs", plan_vanilla.clone()).with_queue(64, 1))
        .unwrap();

    // Every pre-swap request completes with the OLD plan's exact output.
    for (p, x) in pendings.into_iter().zip(&inputs) {
        let out = p.wait().expect("queued request must drain, not drop");
        assert_eq!(out, run_direct(&plan_fused, x));
    }

    // Post-swap submits execute the new plan.
    let x = input_for("quickstart", 999);
    assert_eq!(handle.infer("qs", x.clone()).unwrap(), run_direct(&plan_vanilla, &x));

    // Metrics survived: same id accumulated across both backends, and
    // nothing was counted as a shutdown drop.
    let m = handle.metrics();
    let mm = m.model("qs").unwrap();
    assert_eq!(mm.completed(), total + 1);
    assert_eq!(mm.shutdown_drops(), 0);
    assert_eq!(mm.queue_depth(), 0);

    drop(handle);
    server.shutdown();
}

#[test]
fn retired_model_rejects_submits_and_keeps_metrics() {
    let server = MultiModelServer::new();
    let handle = server.handle();
    let plan = Planner::for_model(zoo::tiny_cnn()).plan().unwrap();
    handle.deploy(ModelSpec::plan("tiny", plan)).unwrap();
    handle.infer("tiny", input_for("tiny", 5)).unwrap();

    handle.retire("tiny").unwrap();
    let err = handle.submit("tiny", input_for("tiny", 6)).unwrap_err();
    assert_eq!(err, ServeError::UnknownModel { model_id: "tiny".into() });

    // Post-mortem metrics stay queryable.
    assert_eq!(handle.metrics().model("tiny").unwrap().completed(), 1);

    // The id can be redeployed after retirement.
    let plan = Planner::for_model(zoo::tiny_cnn()).plan().unwrap();
    handle.deploy(ModelSpec::plan("tiny", plan)).unwrap();
    handle.infer("tiny", input_for("tiny", 7)).unwrap();
    assert_eq!(handle.metrics().model("tiny").unwrap().completed(), 2);

    drop(handle);
    server.shutdown();
}
