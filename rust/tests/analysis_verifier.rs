//! Static-verifier integration: the full zoo × strategy matrix analyzes
//! clean, every injected defect class is flagged, a corrupted-pool plan
//! JSON is rejected by [`PlanRegistry`] sync (never deployed) with a
//! structured diagnostic naming the offending buffer and byte range, and
//! the analyzer-gated compile leaves the hot path bit-identical and
//! allocation-free.

use std::path::PathBuf;

use msf_cnn::analysis::{self, AnalysisInput, DefectClass};
use msf_cnn::coordinator::{MultiModelServer, PlanRegistry};
use msf_cnn::exec::CompiledPlan;
use msf_cnn::ops::ParamGen;
use msf_cnn::optimizer::{strategy, Constraints, Plan, Planner, PlanStrategy};
use msf_cnn::zoo;

const STRATEGIES: [(&str, &dyn PlanStrategy); 5] = [
    ("p1", &strategy::P1),
    ("p2", &strategy::P2),
    ("vanilla", &strategy::Vanilla),
    ("head-fusion", &strategy::HeadFusion),
    ("streamnet", &strategy::StreamNet),
];

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("msfcnn-av-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn quickstart_plan() -> Plan {
    Planner::for_model(zoo::quickstart()).plan().unwrap()
}

fn classes(report: &analysis::AnalysisReport) -> Vec<DefectClass> {
    report.findings.iter().map(|f| f.class).collect()
}

// ------------------------------------------------------------ clean matrix

/// Every plannable `(zoo model, strategy)` pair verifies with zero
/// findings — the analyzer has no false positives on real plans
/// (vanilla chains, fused pyramids, iterative tails, residual stashes).
#[test]
fn full_zoo_strategy_matrix_verifies_clean() {
    let mut verified = 0usize;
    for name in zoo::MODEL_NAMES {
        let m = zoo::by_name(name).unwrap();
        let mut planner = Planner::for_model(m.clone());
        for (sname, s) in STRATEGIES {
            let plan = match planner.plan_with(s, Constraints::none()) {
                Ok(p) => p,
                Err(_) => continue, // infeasible pair: nothing to verify
            };
            let report = analysis::verify_plan(&plan, &m);
            assert!(report.is_clean(), "{name} x {sname}:\n{}", report.render());
            assert!(report.steps_checked > 0, "{name} x {sname}: no steps walked");
            assert!(report.buffers_checked > 0, "{name} x {sname}: no buffers examined");
            verified += 1;
        }
    }
    assert!(verified >= 2 * zoo::MODEL_NAMES.len(), "matrix mostly infeasible: {verified}");
}

// -------------------------------------------------------- defect injection

/// Layout-level mutations of a known-good plan: each corruption is
/// flagged with its own defect class (and located: buffer + byte range
/// where applicable), not just "invalid".
#[test]
fn injected_layout_defects_are_flagged_by_class() {
    let m = zoo::quickstart();
    let good = quickstart_plan();
    assert!(analysis::verify_plan(&good, &m).is_clean());

    // Corrupt the watermark.
    let mut p = good.clone();
    p.pool.as_mut().unwrap().watermark += 4;
    assert!(classes(&analysis::verify_plan(&p, &m)).contains(&DefectClass::WatermarkMismatch));

    // Shift a buffer onto a live neighbor.
    let mut p = good.clone();
    {
        let pool = p.pool.as_mut().unwrap();
        assert!(pool.buffers.len() >= 2);
        let (off, birth, death) =
            (pool.buffers[0].offset, pool.buffers[0].birth, pool.buffers[0].death);
        pool.buffers[1].offset = off;
        pool.buffers[1].birth = birth;
        pool.buffers[1].death = death;
    }
    let report = analysis::verify_plan(&p, &m);
    assert!(classes(&report).contains(&DefectClass::LayoutCollision), "{}", report.render());
    let col = report
        .findings
        .iter()
        .find(|f| f.class == DefectClass::LayoutCollision)
        .unwrap();
    assert!(!col.buffer.is_empty(), "collision names no buffer");
    assert!(col.bytes.is_some(), "collision carries no byte range");

    // Truncate a lifetime to empty.
    let mut p = good.clone();
    {
        let b = &mut p.pool.as_mut().unwrap().buffers[0];
        b.death = b.birth;
    }
    assert!(classes(&analysis::verify_plan(&p, &m)).contains(&DefectClass::LifetimeViolation));

    // Push a buffer past the pool.
    let mut p = good.clone();
    {
        let pool = p.pool.as_mut().unwrap();
        pool.buffers[0].offset = pool.pool_bytes;
    }
    assert!(classes(&analysis::verify_plan(&p, &m)).contains(&DefectClass::OutOfPool));

    // Shrink one buffer: still self-consistent enough to dodge the
    // watermark? No — and even when it would be, the cross-check against
    // a fresh schedule replay reports the divergence.
    let mut p = good.clone();
    p.pool.as_mut().unwrap().buffers[0].bytes -= 4;
    let report = analysis::verify_plan(&p, &m);
    assert!(
        classes(&report)
            .iter()
            .any(|c| matches!(c, DefectClass::LayoutDivergence | DefectClass::WatermarkMismatch)),
        "{}",
        report.render()
    );

    // Break the span chain itself.
    let mut p = good.clone();
    p.setting.spans[0].0 += 1;
    assert!(classes(&analysis::verify_plan(&p, &m)).contains(&DefectClass::MalformedSetting));
}

/// Step-level mutations of a compiled plan's symbolic view: reordered
/// steps, aliased ranges, and shrunk buffers each land in their own
/// defect class.
#[test]
fn injected_dataflow_defects_are_flagged_by_class() {
    let m = zoo::quickstart();
    let setting = Planner::for_model(m.clone())
        .plan_with(&strategy::Vanilla, Constraints::none())
        .unwrap()
        .setting;
    let compiled = CompiledPlan::compile(m, setting);
    let good = AnalysisInput::from_compiled(&compiled);
    assert!(analysis::verify_dataflow(&good).is_clean());

    // Reorder steps: a consumer now runs before its producer.
    let mut input = good.clone();
    assert!(input.steps.len() >= 2);
    input.steps.swap(0, 1);
    assert!(classes(&analysis::verify_dataflow(&input)).contains(&DefectClass::DefBeforeUse));

    // Alias a step's output onto its input. Step 0 reads the external
    // input (no pool read), so pick the first step with a pool read.
    let mut input = good.clone();
    let step = input
        .steps
        .iter()
        .find(|s| !s.reads.is_empty() && !s.writes.is_empty())
        .expect("a step reading and writing the pool");
    let (rbuf, wbuf) = (step.reads[0].buf, step.writes[0].buf);
    input.buffers[wbuf].off = input.buffers[rbuf].off;
    assert!(classes(&analysis::verify_dataflow(&input)).contains(&DefectClass::Hazard));

    // Shrink a buffer under its accesses.
    let mut input = good.clone();
    let out = input.output;
    input.buffers[out].elems /= 2;
    assert!(classes(&analysis::verify_dataflow(&input)).contains(&DefectClass::ShapeMismatch));
}

// ------------------------------------------------------ deploy-time gates

/// A plan JSON whose pool layout was corrupted on disk is rejected by
/// `PlanRegistry` sync — never deployed — and the diagnostic names the
/// offending buffer and byte range.
#[test]
fn registry_sync_rejects_corrupted_pool_json_with_located_diagnostic() {
    let dir = tmp_dir("corrupt");
    let mut bad = quickstart_plan();
    let label0 = {
        let pool = bad.pool.as_mut().unwrap();
        let (off, birth, death) =
            (pool.buffers[0].offset, pool.buffers[0].birth, pool.buffers[0].death);
        pool.buffers[1].offset = off;
        pool.buffers[1].birth = birth;
        pool.buffers[1].death = death;
        pool.buffers[0].label.clone()
    };
    // Written raw: the corruption is only caught when the file is loaded.
    std::fs::write(dir.join("quickstart.plan.json"), bad.to_json()).unwrap();

    let mut registry = PlanRegistry::open(&dir).unwrap();
    let server = MultiModelServer::new();
    let handle = server.handle();
    let report = registry.sync(&handle).unwrap();

    assert!(registry.is_empty(), "corrupted plan entered the registry");
    assert!(handle.model_ids().is_empty(), "corrupted plan was deployed");
    assert_eq!(report.errors.len(), 1, "{report:?}");
    let err = &report.errors[0].1;
    assert!(err.contains(&label0), "diagnostic does not name the buffer: {err}");
    assert!(err.contains("bytes ["), "diagnostic carries no byte range: {err}");

    drop(handle);
    server.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

/// A *self-consistent* hand-edit (every offset shifted into a grown
/// pool, watermark still correct) parses and validates — only the
/// cross-check against a fresh schedule replay catches it. The scan's
/// verdict says why, and the previous good version stays live.
#[test]
fn registry_scan_verdicts_reject_self_consistent_divergence() {
    let dir = tmp_dir("diverge");
    let good = quickstart_plan();
    good.save(dir.join("quickstart.plan.json")).unwrap();

    let mut registry = PlanRegistry::open(&dir).unwrap();
    let server = MultiModelServer::new();
    let handle = server.handle();
    let report = registry.sync(&handle).unwrap();
    assert_eq!(report.added, vec!["quickstart".to_string()]);
    assert_eq!(report.verdicts.len(), 1);
    assert!(report.verdicts[0].is_clean(), "{:?}", report.verdicts[0]);
    let x = ParamGen::new(7).fill(zoo::quickstart().shapes[0].elems() as usize, 2.0);
    let before = handle.infer("quickstart", x.clone()).unwrap();

    // Hand-edit: shift every buffer up 8 bytes inside a pool grown by 8.
    // `Plan::validate` accepts this (internally consistent) layout.
    let mut shifted = good.clone();
    {
        let pool = shifted.pool.as_mut().unwrap();
        for b in &mut pool.buffers {
            b.offset += 8;
        }
        pool.pool_bytes += 8;
    }
    shifted.validate().expect("shifted layout is self-consistent");
    std::fs::write(dir.join("quickstart.plan.json"), shifted.to_json()).unwrap();

    let report = registry.sync(&handle).unwrap();
    assert!(report.updated.is_empty(), "divergent plan was swapped in: {report:?}");
    assert_eq!(report.errors.len(), 1, "{report:?}");
    let verdict = report
        .verdicts
        .iter()
        .find(|v| v.model_id == "quickstart")
        .expect("verdict for the rejected file");
    assert!(!verdict.is_clean());
    assert!(
        verdict.findings.iter().any(|f| f.contains("layout-divergence")),
        "{verdict:?}"
    );

    // The previous good version still serves, bit-identically.
    assert_eq!(registry.latest("quickstart").unwrap().version, 1);
    assert_eq!(handle.infer("quickstart", x).unwrap(), before);

    drop(handle);
    server.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

// ------------------------------------------------------ hot-path parity

/// The analyzer-backed compile-time gate changes nothing at runtime:
/// warm runs stay allocation-free with bit-identical logits, and the
/// compiled artifact itself verifies clean (`verify_compiled`).
#[test]
fn analyzer_gated_compile_keeps_hot_path_allocation_free_and_bit_identical() {
    for model in [zoo::quickstart(), zoo::tiny_cnn()] {
        let name = model.name.clone();
        let setting = Planner::for_model(model.clone()).setting().unwrap();
        let compiled = CompiledPlan::compile(model.clone(), setting);
        let report = analysis::verify_compiled(&compiled);
        assert!(report.is_clean(), "{name}:\n{}", report.render());

        let mut pool = compiled.make_pool();
        let allocs0 = pool.storage_allocs();
        let x_data = ParamGen::new(17).fill(model.shapes[0].elems() as usize, 2.0);
        let s = model.shapes[0];
        let x = msf_cnn::ops::Tensor::from_data(
            s.h as usize,
            s.w as usize,
            s.c as usize,
            x_data,
        );
        let mut out_a = vec![0.0f32; compiled.output_len()];
        let mut out_b = vec![0.0f32; compiled.output_len()];
        let macs_a = compiled.run_into(x.as_map(), &mut pool, &mut out_a);
        let macs_b = compiled.run_into(x.as_map(), &mut pool, &mut out_b);
        assert_eq!(macs_a, macs_b, "{name}: MAC count drifted across warm runs");
        assert_eq!(out_a, out_b, "{name}: warm rerun diverged");
        assert_eq!(pool.storage_allocs(), allocs0, "{name}: hot path allocated");
    }
}
