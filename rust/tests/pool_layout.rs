//! Property coverage for `memory::planner`'s lifetime/offset assignment
//! (seeded in-tree runner, `msf_cnn::util::prop`):
//!
//! 1. Offset-assigned buffers never overlap while both alive — including
//!    residual-extended lifetimes and the death clamp on the final
//!    tensor — and the vanilla pool is *exactly* the max concurrent
//!    footprint (`pool_bytes == watermark`: offset assignment adds no
//!    fragmentation on chain schedules).
//! 2. The generalized fused-schedule layout (`plan_layout`) reproduces
//!    the interpreted engine's measured arena peak as its watermark, on
//!    random chains under both the min-RAM and vanilla strategies.

use msf_cnn::exec::Engine;
use msf_cnn::memory::{assign_offsets, max_concurrent, plan_layout, plan_pool, schedule_intervals};
use msf_cnn::model::{Activation, Layer, ModelChain, TensorShape};
use msf_cnn::ops::{ParamGen, Tensor};
use msf_cnn::optimizer::{strategy, Constraints, Planner, PlanStrategy};
use msf_cnn::util::prop::{check, Gen};
use msf_cnn::{memory::Arena, zoo};

/// Random small chain mixing plain convs with MBV2-style residual blocks
/// (stride-1 expand/dw/project with a skip), optionally ending in the
/// GlobalPool+Dense tail — every lifetime shape the planner handles.
fn random_chain(g: &mut Gen) -> ModelChain {
    let mut layers: Vec<Layer> = Vec::new();
    let mut c = *g.pick(&[2u32, 3, 4]);
    let mut h = g.u32_in(12, 20);
    let mut w = g.u32_in(12, 20);
    let input = TensorShape::new(h, w, c);
    let blocks = g.usize_in(1, 3);
    for bi in 0..blocks {
        if g.bool() && h >= 6 && w >= 6 {
            // Residual block: v_{expand-in} skips into the project output.
            let e = c * 2;
            let i0 = layers.len();
            layers.push(Layer::pointwise(format!("e{bi}"), c, e, Activation::Relu6));
            layers.push(Layer::dwconv(format!("d{bi}"), 3, 1, 1, e, Activation::Relu6));
            layers.push(
                Layer::pointwise(format!("p{bi}"), e, c, Activation::None).with_residual(i0),
            );
        } else {
            let k = *g.pick(&[1u32, 3]);
            let s = if k == 3 && h > 8 && w > 8 { *g.pick(&[1u32, 2]) } else { 1 };
            let p = if k == 3 { 1 } else { 0 };
            let cout = *g.pick(&[2u32, 4, 6]);
            layers.push(Layer::conv(format!("c{bi}"), k, s, p, c, cout, Activation::Relu6));
            c = cout;
            h = (h + 2 * p - k) / s + 1;
            w = (w + 2 * p - k) / s + 1;
        }
    }
    if g.bool() {
        layers.push(Layer::global_pool("gp", c));
        layers.push(Layer::dense("fc", c, *g.pick(&[4u32, 10])));
    }
    ModelChain::new("prop", input, layers)
}

fn input_for(m: &ModelChain, seed: u64) -> Tensor {
    let s = m.shapes[0];
    Tensor::from_data(
        s.h as usize,
        s.w as usize,
        s.c as usize,
        ParamGen::new(seed).fill(s.elems() as usize, 2.0),
    )
}

#[test]
fn vanilla_pool_never_overlaps_and_is_exactly_the_watermark() {
    check("vanilla-pool", 60, |g| {
        let m = random_chain(g);
        let n = m.num_layers();
        let plan = plan_pool(&m);

        // Pairwise: lifetime overlap => disjoint pool space.
        for (i, a) in plan.buffers.iter().enumerate() {
            for b in plan.buffers.iter().skip(i + 1) {
                let live = !(a.death < b.birth || b.death < a.birth);
                let space = a.offset < b.offset + b.bytes && b.offset < a.offset + a.bytes;
                if live && space {
                    return Err(format!("v{} and v{} collide", a.tensor, b.tensor));
                }
            }
        }
        // Death clamp: no buffer outlives the final layer step, and the
        // output tensor v_n dies exactly at step n-1.
        for b in &plan.buffers {
            if b.death > n - 1 {
                return Err(format!("v{} death {} past final step {}", b.tensor, b.death, n - 1));
            }
        }
        if let Some(out) = plan.buffers.iter().find(|b| b.tensor == n) {
            if out.death != n - 1 {
                return Err(format!("v{n} death {} != clamped {}", out.death, n - 1));
            }
        }
        // Residual-extended lifetimes: skip sources live to the consumer.
        for (j, l) in m.layers.iter().enumerate() {
            if let Some(src) = l.residual_from {
                let buf = plan
                    .buffers
                    .iter()
                    .find(|p| p.tensor == src)
                    .ok_or_else(|| format!("stash source v{src} missing"))?;
                if buf.death < j {
                    return Err(format!("v{src} freed at {} before consumer {j}", buf.death));
                }
            }
        }
        // Zero fragmentation on the chain schedule: the pool is exactly
        // the max concurrent footprint.
        let items: Vec<(u64, usize, usize)> = plan
            .buffers
            .iter()
            .map(|p| (p.bytes, p.birth, p.death + 1))
            .collect();
        let watermark = max_concurrent(&items);
        if plan.pool_bytes != watermark {
            return Err(format!(
                "pool {} != max concurrent footprint {} on {}",
                plan.pool_bytes,
                watermark,
                m.describe()
            ));
        }
        Ok(())
    });
}

#[test]
fn fused_layout_watermark_equals_interpreted_measured_peak() {
    check("fused-layout-vs-engine", 25, |g| {
        let m = random_chain(g);
        let engine = Engine::new(m.clone());
        let x = input_for(&m, g.seed);
        let mut planner = Planner::for_model(m.clone());
        for s in [&strategy::P1 as &dyn PlanStrategy, &strategy::Vanilla] {
            let Ok(plan) = planner.plan_with(s, Constraints::none()) else {
                continue;
            };
            let layout = plan_layout(&m, &plan.setting);
            let mut arena = Arena::unbounded();
            let r = engine
                .run(&plan.setting, &x, &mut arena)
                .map_err(|e| format!("{} oom: {e}", s.name()))?;
            if layout.watermark != r.peak_ram {
                return Err(format!(
                    "{}: layout watermark {} != measured {} on {}",
                    s.name(),
                    layout.watermark,
                    r.peak_ram,
                    plan.setting.describe()
                ));
            }
            if layout.pool_bytes < layout.watermark {
                return Err(format!("{}: pool below watermark", s.name()));
            }
            // Half-open lifetime overlap => disjoint pool space.
            for (i, a) in layout.buffers.iter().enumerate() {
                for b in layout.buffers.iter().skip(i + 1) {
                    let live = a.birth < b.death && b.birth < a.death;
                    let space =
                        a.offset < b.offset + b.bytes && b.offset < a.offset + a.bytes;
                    if live && space {
                        return Err(format!("'{}' and '{}' collide", a.label, b.label));
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn runtime_lifetimes_cover_accounting_lifetimes() {
    check("rt-lifetimes", 40, |g| {
        let m = random_chain(g);
        let setting = Planner::for_model(m.clone())
            .setting()
            .map_err(|e| format!("{e:#}"))?;
        for s in schedule_intervals(&m, &setting) {
            if s.birth >= s.death {
                return Err(format!("'{}' has empty lifetime", s.label));
            }
            if s.rt_death < s.death {
                return Err(format!("'{}' runtime lifetime shorter than accounting", s.label));
            }
        }
        Ok(())
    });
}

#[test]
fn generic_offset_assignment_is_collision_free() {
    // Pure-interval property (no model): random half-open intervals.
    check("assign-offsets", 120, |g| {
        let n = g.usize_in(2, 12);
        let items: Vec<(u64, usize, usize)> = (0..n)
            .map(|_| {
                let birth = g.usize_in(0, 20);
                let len = g.usize_in(1, 10);
                (g.usize_in(1, 512) as u64, birth, birth + len)
            })
            .collect();
        let (offsets, total) = assign_offsets(&items);
        let watermark = max_concurrent(&items);
        if total < watermark {
            return Err(format!("total {total} below watermark {watermark}"));
        }
        for i in 0..n {
            for j in i + 1..n {
                let (sb, bb, db) = items[i];
                let (sj, bj, dj) = items[j];
                let live = bb < dj && bj < db;
                let space = offsets[i] < offsets[j] + sj && offsets[j] < offsets[i] + sb;
                if live && space {
                    return Err(format!("items {i} and {j} collide: {items:?} {offsets:?}"));
                }
                if offsets[i] + sb > total || offsets[j] + sj > total {
                    return Err("buffer overruns total".into());
                }
            }
        }
        Ok(())
    });
}

#[test]
fn zoo_models_layouts_are_exact_on_vanilla() {
    // Deterministic anchor on the real zoo: vanilla watermark is the
    // Eq. 5 closed form and the pool is fragmentation-free.
    for name in ["quickstart", "tiny", "lenet", "kws", "mn2-vww5"] {
        let m = zoo::by_name(name).unwrap();
        let vanilla = Planner::for_model(m.clone())
            .strategy(strategy::Vanilla)
            .setting()
            .unwrap();
        let layout = plan_layout(&m, &vanilla);
        assert_eq!(layout.watermark, m.vanilla_peak_ram(), "{name}");
        assert_eq!(
            plan_pool(&m).pool_bytes,
            m.vanilla_peak_ram(),
            "{name}: vanilla pool fragmented"
        );
    }
}
