//! Optimizer benchmarks: DAG construction and P1/P2 solve times on the
//! paper's three evaluation models — substantiating §6's "this process
//! can be done in few seconds" (we target milliseconds) and App. D's
//! polynomial-time claim.

use msf_cnn::graph::FusionDag;
use msf_cnn::optimizer::{
    heuristic_head_fusion, minimize_macs, minimize_ram, minimize_ram_unconstrained,
    streamnet_single_block,
};
use msf_cnn::util::bench::Bencher;
use msf_cnn::zoo;

fn main() {
    let b = Bencher::default();
    println!("== optimizer benches (paper §6 / App. D) ==");

    for (label, model) in zoo::paper_models() {
        b.run(&format!("dag-build/{label}"), || FusionDag::build(&model, None));

        let dag = FusionDag::build(&model, None);
        b.run(&format!("p1-unconstrained/{label}"), || {
            minimize_ram_unconstrained(&dag).unwrap()
        });
        b.run(&format!("p1-constrained-F1.3/{label}"), || {
            minimize_ram(&dag, 1.3)
        });
        b.run(&format!("p2-64kB/{label}"), || minimize_macs(&dag, 64_000));
        b.run(&format!("baseline-heuristic/{label}"), || {
            heuristic_head_fusion(&dag)
        });
        b.run(&format!("baseline-streamnet/{label}"), || {
            streamnet_single_block(&dag, None)
        });
    }

    // The full Table-1 grid per model — the paper's end-user operation.
    for (label, model) in zoo::paper_models() {
        let dag = FusionDag::build(&model, None);
        b.run(&format!("full-constraint-grid/{label}"), || {
            let mut acc = 0u64;
            for f_max in [1.1, 1.2, 1.3, 1.4, 1.5] {
                if let Some(s) = minimize_ram(&dag, f_max) {
                    acc ^= s.cost.peak_ram;
                }
            }
            if let Some(s) = minimize_ram_unconstrained(&dag) {
                acc ^= s.cost.peak_ram;
            }
            for p in [16u64, 32, 64, 128, 256] {
                if let Some(s) = minimize_macs(&dag, p * 1000) {
                    acc ^= s.cost.macs;
                }
            }
            acc
        });
    }
}
