//! Optimizer benchmarks: DAG construction and P1/P2 solve times on the
//! paper's three evaluation models — substantiating §6's "this process
//! can be done in few seconds" (we target milliseconds) and App. D's
//! polynomial-time claim. Solvers run through the [`PlanStrategy`] trait
//! objects the planner dispatches on.

use msf_cnn::graph::{DagOptions, FusionDag};
use msf_cnn::optimizer::strategy::{HeadFusion, P1, P2, StreamNet};
use msf_cnn::optimizer::{Constraint, Constraints, PlanStrategy};
use msf_cnn::util::bench::Bencher;
use msf_cnn::zoo;

fn main() {
    let b = Bencher::default();
    println!("== optimizer benches (paper §6 / App. D) ==");

    let none = Constraints::none();
    for (label, model) in zoo::paper_models() {
        b.run(&format!("dag-build/{label}"), || {
            FusionDag::build(&model, DagOptions::default())
        });

        let dag = FusionDag::build(&model, DagOptions::default());
        b.run(&format!("p1-unconstrained/{label}"), || {
            P1.solve(&dag, &none).unwrap()
        });
        let f13 = none.with(Constraint::Overhead(1.3));
        b.run(&format!("p1-constrained-F1.3/{label}"), || {
            P1.solve(&dag, &f13)
        });
        let p64 = none.with(Constraint::Ram(64_000));
        b.run(&format!("p2-64kB/{label}"), || P2.solve(&dag, &p64));
        b.run(&format!("baseline-heuristic/{label}"), || {
            HeadFusion.solve(&dag, &none)
        });
        b.run(&format!("baseline-streamnet/{label}"), || {
            StreamNet.solve(&dag, &none)
        });
    }

    // The full Table-1 grid per model — the paper's end-user operation.
    for (label, model) in zoo::paper_models() {
        let dag = FusionDag::build(&model, DagOptions::default());
        b.run(&format!("full-constraint-grid/{label}"), || {
            let mut acc = 0u64;
            for f_max in [1.1, 1.2, 1.3, 1.4, 1.5, f64::INFINITY] {
                let c = none.with(Constraint::Overhead(f_max));
                if let Some(s) = P1.solve(&dag, &c) {
                    acc ^= s.cost.peak_ram;
                }
            }
            for p in [16u64, 32, 64, 128, 256] {
                let c = none.with(Constraint::Ram(p * 1000));
                if let Some(s) = P2.solve(&dag, &c) {
                    acc ^= s.cost.macs;
                }
            }
            acc
        });
    }
}
