//! PlanBatch benchmarks: the multi-configuration planning sweep
//! (models × boards × budgets), serial vs the scoped worker pool —
//! substantiating that the parallel coordinator path wins wall-clock on
//! multi-core while staying bit-identical to the serial solver.

use msf_cnn::graph::{DagOptions, FusionDag};
use msf_cnn::mcu::BOARDS;
use msf_cnn::optimizer::{
    strategy, Constraint, Constraints, PlanBatch, PlanJob, Planner, PlanOutcome,
};
use msf_cnn::report::{F_MAX_GRID, P_MAX_GRID_KB};
use msf_cnn::util::bench::Bencher;
use msf_cnn::zoo;

/// The co-design sweep: every paper model plus the small zoo, each under
/// the full paper constraint grid and a fit-the-board job per Table 4
/// board.
fn build_batch() -> PlanBatch {
    let mut batch = PlanBatch::new();
    let p_grid_bytes: Vec<u64> = P_MAX_GRID_KB.iter().map(|&p| p * 1000).collect();
    let mut names: Vec<&str> = vec!["quickstart", "tiny", "lenet", "kws"];
    names.extend(["mbv2-w0.35", "mn2-vww5", "mn2-320k"]);
    for name in names {
        let idx = batch.add_model(name, zoo::by_name(name).unwrap());
        batch.push_grid(idx, F_MAX_GRID, &p_grid_bytes);
        for board in BOARDS {
            batch.push(PlanJob::fit_board(idx, board));
        }
    }
    batch
}

fn assert_identical(a: &[PlanOutcome], b: &[PlanOutcome]) {
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(b) {
        let same = match (&x.setting, &y.setting) {
            (None, None) => true,
            (Some(s), Some(t)) => {
                s.spans == t.spans && s.cost.peak_ram == t.cost.peak_ram && s.cost.macs == t.cost.macs
            }
            _ => false,
        };
        assert!(same, "parallel outcome diverged for model {}", x.job.model);
    }
}

fn main() {
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    let batch = build_batch();
    println!(
        "== plan-batch benches ({} models, {} configurations, {} hw threads) ==",
        batch.models().len(),
        batch.jobs().len(),
        threads
    );

    // Correctness first: the acceptance bar is bit-identical settings.
    let serial = batch.solve_serial();
    assert_identical(&serial, &batch.solve_with_threads(1));
    assert_identical(&serial, &batch.solve());
    println!("parallel sweep verified bit-identical to serial on all configurations");

    let b = Bencher::quick();
    let rs = b.run("plan-batch/serial", || batch.solve_serial());
    let r1 = b.run("plan-batch/pool-1-thread", || batch.solve_with_threads(1));
    let rp = b.run(&format!("plan-batch/pool-{threads}-threads"), || batch.solve());
    let (hits, misses) = batch.memo_stats();
    println!(
        "edge-cost memo: {hits} hits / {misses} misses across repeated solves \
         ({:.1}% of DAG rebuild cost served from cache)",
        100.0 * hits as f64 / (hits + misses).max(1) as f64
    );

    let speedup = rs.mean.as_secs_f64() / rp.mean.as_secs_f64().max(1e-12);
    let overhead = r1.mean.as_secs_f64() / rs.mean.as_secs_f64().max(1e-12);
    println!(
        "speedup vs serial: {speedup:.2}x on {threads} threads (pool overhead at 1 thread: {overhead:.2}x)"
    );
    // Not a hard assert: a cgroup CPU quota can make available_parallelism
    // lie about usable cores; the line above is the acceptance evidence.
    if threads > 1 && speedup <= 1.0 {
        println!("WARN: parallel sweep did not beat serial — constrained CPU environment?");
    }

    facade_overhead(&b);
}

/// The grid of P1/P2 solves both facade variants run per model.
fn solve_grid_direct(dag: &FusionDag) -> u64 {
    use msf_cnn::optimizer::PlanStrategy;
    let mut acc = 0u64;
    for &f_max in F_MAX_GRID {
        let c = Constraints::none().with(Constraint::Overhead(f_max));
        if let Some(s) = strategy::P1.solve(dag, &c) {
            acc ^= s.cost.peak_ram;
        }
    }
    for &p_kb in P_MAX_GRID_KB {
        let c = Constraints::none().with(Constraint::Ram(p_kb * 1000));
        if let Some(s) = strategy::P2.solve(dag, &c) {
            acc ^= s.cost.macs;
        }
    }
    acc
}

fn solve_grid_facade(planner: &mut Planner) -> u64 {
    let mut acc = 0u64;
    for &f_max in F_MAX_GRID {
        let c = Constraints::none().with(Constraint::Overhead(f_max));
        if let Ok(p) = planner.plan_with(&strategy::P1, c) {
            acc ^= p.cost().peak_ram;
        }
    }
    for &p_kb in P_MAX_GRID_KB {
        let c = Constraints::none().with(Constraint::Ram(p_kb * 1000));
        if let Ok(p) = planner.plan_with(&strategy::P2, c) {
            acc ^= p.cost().macs;
        }
    }
    acc
}

/// Planner-facade overhead: the builder path (DAG ownership, memoized
/// edge costs, `Plan` assembly) versus raw `PlanStrategy::solve` calls
/// on a hand-built DAG, on the full paper constraint grid. Cold = a
/// fresh planner per iteration (worst case); warm = the intended reuse
/// pattern.
fn facade_overhead(b: &Bencher) {
    println!("== planner facade vs direct strategy calls ==");
    let models = zoo::paper_models();

    // Identical outcomes first: the facade must solve the same grid.
    for (_, m) in &models {
        let dag = FusionDag::build(m, DagOptions::default());
        let mut planner = Planner::for_model(m.clone());
        assert_eq!(
            solve_grid_direct(&dag),
            solve_grid_facade(&mut planner),
            "facade diverged from the direct path on {}",
            m.name
        );
    }

    let rd = b.run("facade/direct-strategy", || {
        models
            .iter()
            .map(|(_, m)| solve_grid_direct(&FusionDag::build(m, DagOptions::default())))
            .fold(0u64, |a, x| a ^ x)
    });
    let rc = b.run("facade/planner-cold", || {
        models
            .iter()
            .map(|(_, m)| solve_grid_facade(&mut Planner::for_model(m.clone())))
            .fold(0u64, |a, x| a ^ x)
    });
    let mut warm: Vec<Planner> =
        models.iter().map(|(_, m)| Planner::for_model(m.clone())).collect();
    let rw = b.run("facade/planner-warm", || {
        warm.iter_mut().map(solve_grid_facade).fold(0u64, |a, x| a ^ x)
    });

    let cold_ratio = rc.mean.as_secs_f64() / rd.mean.as_secs_f64().max(1e-12);
    let warm_ratio = rw.mean.as_secs_f64() / rd.mean.as_secs_f64().max(1e-12);
    println!(
        "facade overhead: cold {cold_ratio:.2}x, warm {warm_ratio:.2}x vs direct \
         (1.00x = free; warm < 1 ⇒ the shared memo wins)"
    );
    if cold_ratio > 1.1 {
        println!("WARN: cold planner facade exceeded 10% overhead vs direct calls");
    }
}
