//! Regenerates every paper table & figure and times each generator —
//! `cargo bench` therefore *prints the reproduction itself* (the rows the
//! paper reports) alongside its cost.

use msf_cnn::report;
use msf_cnn::util::bench::Bencher;

fn main() {
    println!("== paper tables & figures (regenerated) ==\n");
    let (_, t1) = report::table1();
    println!("{t1}");
    let (_, t2) = report::table2();
    println!("{t2}");
    let (_, t3) = report::table3();
    println!("{t3}");
    let (_, t5) = report::table5();
    println!("{t5}");
    let (_, f2) = report::fig2_pooling();
    println!("{f2}");
    let (_, f3) = report::fig3_dense();
    println!("{f3}");
    let (_, f4) = report::fig4_series();
    println!("Fig 4 series (CSV):\n{f4}");
    let (_, ab1) = report::ablation_cache_schemes();
    println!("{ab1}");
    let qm = msf_cnn::zoo::quickstart();
    let (_, ab2) = report::ablation_output_granularity(&qm, 0, 3);
    println!("{ab2}");

    println!("== generator timings ==");
    let b = Bencher::quick();
    b.run("table1", report::table1);
    b.run("table2", report::table2);
    b.run("table3", report::table3);
    b.run("table5", report::table5);
    b.run("fig4", report::fig4_series);
    b.run("ablation-cache-schemes", report::ablation_cache_schemes);
}
