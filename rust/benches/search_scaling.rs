//! Search-space scaling (paper App. D): the pruned P1 search must scale
//! polynomially (O(V³)) where exhaustive enumeration scales as 2^{V-2}.
//! Prints both series over growing synthetic chains so the crossover is
//! visible in the bench log.

use msf_cnn::graph::{enumerate_paths, DagOptions, FusionDag};
use msf_cnn::model::{Activation, Layer, ModelChain, TensorShape};
use msf_cnn::optimizer::strategy::P1;
use msf_cnn::optimizer::{exhaustive_p1, Constraint, Constraints, PlanStrategy};
use msf_cnn::util::bench::Bencher;

fn chain(n: usize) -> ModelChain {
    let layers = (0..n)
        .map(|i| {
            let s = if i % 3 == 2 { 2 } else { 1 };
            Layer::conv(format!("c{i}"), 3, s, 1, 4, 4, Activation::Relu6)
        })
        .collect();
    ModelChain::new(format!("chain{n}"), TensorShape::new(96, 96, 4), layers)
}

fn main() {
    println!("== search scaling (App. D: O(2^V) exhaustive vs O(V^3) pruned) ==");
    let quick = Bencher::quick();

    let f13 = Constraints::none().with(Constraint::Overhead(1.3));

    // Path-count growth (the 2^{V-2} fact itself).
    for n in [4usize, 8, 12, 16] {
        let dag = FusionDag::build(&chain(n), DagOptions::default());
        let paths = enumerate_paths(&dag).len();
        println!("chain n={n:<3} edges={:<5} complete-paths={paths}", dag.num_edges());
    }

    // Exhaustive blows up quickly; stop where it stays sane.
    for n in [6usize, 10, 14] {
        let dag = FusionDag::build(&chain(n), DagOptions::default());
        quick.run(&format!("exhaustive-p1/n={n}"), || exhaustive_p1(&dag, 1.3));
    }

    // The pruned solver keeps scaling to real model depths.
    for n in [6usize, 14, 24, 40, 54, 80] {
        let dag = FusionDag::build(&chain(n), DagOptions::default());
        quick.run(&format!("pruned-p1/n={n}"), || P1.solve(&dag, &f13));
    }

    // Ablation: depth-capped DAGs (smaller search spaces, DESIGN.md §ablations).
    let m = chain(54);
    for cap in [4usize, 8, 16] {
        let dag = FusionDag::build(&m, DagOptions::default().max_depth(cap));
        quick.run(&format!("pruned-p1/n=54,depth-cap={cap}"), || {
            P1.solve(&dag, &f13)
        });
    }
}
