//! PJRT runtime + serving benchmarks: artifact compile time, single-shot
//! execution latency per entry point, and coordinator throughput. Skips
//! politely when `artifacts/` has not been built.

use msf_cnn::coordinator::{InferenceServer, ServerConfig};
use msf_cnn::ops::ParamGen;
use msf_cnn::runtime::Runtime;
use msf_cnn::util::bench::Bencher;

fn main() {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("artifacts/ not built (run `make artifacts`); skipping runtime benches");
        return;
    }
    let b = Bencher::default();
    println!("== runtime benches ==");

    // Compile cost per entry (cold clients each time).
    let quick = Bencher::quick();
    for entry in ["model_vanilla", "model_fused", "conv2d"] {
        quick.run(&format!("compile/{entry}"), || {
            let mut rt = Runtime::open(&dir).unwrap();
            rt.load(entry).unwrap();
        });
    }

    // Hot execution latency.
    let mut rt = Runtime::open(&dir).unwrap();
    let img = ParamGen::new(5).fill(32 * 32 * 3, 2.0);
    for entry in ["model_vanilla", "model_fused"] {
        rt.load(entry).unwrap();
        b.run(&format!("execute/{entry}"), || rt.run_f32(entry, &img).unwrap());
    }
    let pool_in = ParamGen::new(6).fill(7 * 7 * 32, 1.0);
    rt.load("iter_pool").unwrap();
    b.run("execute/iter_pool", || rt.run_f32("iter_pool", &pool_in).unwrap());

    // Coordinator throughput (4 client threads, 200 requests).
    let server = InferenceServer::start(&dir, ServerConfig::default()).unwrap();
    let handle = server.handle();
    handle.infer(img.clone()).unwrap(); // warm
    quick.run("serve-200-requests-4-clients", || {
        let mut joins = Vec::new();
        for t in 0..4u64 {
            let h = server.handle();
            joins.push(std::thread::spawn(move || {
                let mut gen = ParamGen::new(50 + t);
                for _ in 0..50 {
                    let _ = h.infer(gen.fill(32 * 32 * 3, 2.0));
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
    });
    if let Some(stats) = handle.metrics().stats() {
        println!(
            "serving latency: mean {:.0} us, p50 {:.0} us, p99 {:.0} us over {} requests",
            stats.mean_us, stats.p50_us, stats.p99_us, stats.count
        );
    }
    drop(handle);
    server.shutdown();
}
