//! Executor hot-path benchmarks: vanilla vs fused end-to-end inference on
//! the tracked engine, per-block patch execution, and the iterative
//! pool/dense rewrites (Figs. 2–3 compute-cost side: "without any
//! computation overhead").

use msf_cnn::exec::Engine;
use msf_cnn::memory::Arena;
use msf_cnn::ops::{
    dense, global_avg_pool, DenseIter, FusedBlock, GlobalPoolIter, LayerParams, ParamGen, Tensor,
};
use msf_cnn::optimizer::{strategy, Constraints, Planner};
use msf_cnn::util::bench::Bencher;
use msf_cnn::zoo;

fn input_for(m: &msf_cnn::model::ModelChain, seed: u64) -> Tensor {
    let s = m.shapes[0];
    Tensor::from_data(
        s.h as usize,
        s.w as usize,
        s.c as usize,
        ParamGen::new(seed).fill(s.elems() as usize, 2.0),
    )
}

fn main() {
    let b = Bencher::default();
    let quick = Bencher::quick();
    println!("== executor benches ==");

    // End-to-end engine runs (quickstart & vww5).
    for name in ["quickstart", "kws", "mn2-vww5"] {
        let m = zoo::by_name(name).unwrap();
        let engine = Engine::new(m.clone());
        let x = input_for(&m, 1);
        let mut planner = Planner::for_model(m.clone());
        let f = planner.setting().unwrap();
        let v = planner
            .plan_with(&strategy::Vanilla, Constraints::none())
            .unwrap()
            .setting;
        let bench = if name == "mn2-vww5" { &quick } else { &b };
        bench.run(&format!("engine-vanilla/{name}"), || {
            let mut arena = Arena::unbounded();
            engine.run(&v, &x, &mut arena).unwrap().macs
        });
        bench.run(&format!("engine-fused-minram/{name}"), || {
            let mut arena = Arena::unbounded();
            engine.run(&f, &x, &mut arena).unwrap().macs
        });
    }

    // Isolated fused-block pyramid.
    let m = zoo::quickstart();
    let params: Vec<LayerParams> = m
        .layers
        .iter()
        .enumerate()
        .map(|(i, l)| LayerParams::for_layer(l, i))
        .collect();
    let x = input_for(&m, 2);
    b.run("fused-block-3conv/quickstart", || {
        FusedBlock::new(&m, 0, 3, &params).run(&x).1.macs
    });

    // Iterative vs common pooling (7x7x448, the paper's Fig. 2 scale).
    let map = Tensor::from_data(7, 7, 448, ParamGen::new(3).fill(7 * 7 * 448, 1.0));
    b.run("global-pool-common/7x7x448", || global_avg_pool(&map));
    b.run("global-pool-iterative/7x7x448", || {
        let mut it = GlobalPoolIter::new(448, 7, 7);
        for y in 0..7 {
            it.push_rows(&map.row_band(y, 1));
        }
        it.finish()
    });

    // Iterative vs common dense (1024 -> 256, the paper's Fig. 3 scale).
    let mut g = ParamGen::new(4);
    let xv = g.fill(1024, 1.0);
    let w = g.fill(1024 * 256, 0.1);
    let bias = g.fill(256, 0.1);
    b.run("dense-common/1024x256", || dense(&xv, &w, &bias, 256));
    b.run("dense-iterative/1024x256", || {
        let mut it = DenseIter::new(1024, &bias);
        for (i, &xi) in xv.iter().enumerate() {
            it.push(&[xi], &w[i * 256..(i + 1) * 256]);
        }
        it.finish()
    });
}
