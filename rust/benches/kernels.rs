//! Per-kernel microbenchmarks: the engineered interior/halo kernels
//! against their retained naive twins in `ops::reference`, f32 and int8,
//! on representative layer shapes. Every pair is parity-checked before
//! timing — bit-identical for f32, exactly identical for int8 — so a
//! committed speedup can never come from a numerics change. Emits
//! `BENCH_kernels.json` at the repo root through the stable
//! `obs::export` schema; `msfcnn bench check` and CI validate it.
//!
//! Set `MSFCNN_BENCH_SMOKE=1` for a seconds-scale smoke run (CI): fewer
//! iterations, same shapes, same parity asserts, same snapshot schema.

use msf_cnn::model::Activation;
use msf_cnn::obs::export::{kernels_snapshot, validate_kernels_snapshot, KernelRow};
use msf_cnn::ops::reference as naive;
use msf_cnn::ops::{
    avg_pool2d_into, conv2d_into, dense_into, dwconv2d_into, max_pool2d_into, qavg_pool2d_into,
    qconv2d_into, qdense_into, qdwconv2d_into, qmax_pool2d_into, quantize_into, LayerParams,
    MapRef, ParamGen, QLayerParams, QMapRef, QParams,
};
use msf_cnn::util::bench::Bencher;

/// Quantized operand set shared by the int8 twins of one f32 case.
struct QCase {
    xq: Vec<i8>,
    x_qp: QParams,
    qp: QLayerParams,
    out_qp: QParams,
}

fn quantize_case(xf: &[f32], w: &[f32], bias: &[f32], out_f32: &[f32]) -> QCase {
    let x_qp = QParams::observe(xf);
    let mut xq = vec![0i8; xf.len()];
    quantize_into(xf, x_qp, &mut xq);
    let p = LayerParams { weights: w.to_vec(), bias: bias.to_vec() };
    let qp = QLayerParams::from_params(&p, QParams::observe(w));
    QCase { xq, x_qp, qp, out_qp: QParams::observe(out_f32) }
}

fn main() {
    let smoke = std::env::var("MSFCNN_BENCH_SMOKE").is_ok_and(|v| v != "0");
    let b = if smoke { Bencher::quick() } else { Bencher::default() };
    let tag = if smoke { ", smoke" } else { "" };
    println!("== kernel benches (naive reference vs interior/halo{tag}) ==");

    let mut rows: Vec<KernelRow> = Vec::new();
    let mut gen = ParamGen::new(0xBEEF);

    // conv2d 32x32x8, k3 s1 p1, cout 16 — the canonical fused-block body.
    {
        let (h, w_in, cin, k, s, p, cout) = (32usize, 32, 8, 3, 1, 1, 16);
        let shape = format!("{h}x{w_in}x{cin} k{k} s{s} p{p} co{cout}");
        let xf = gen.fill(h * w_in * cin, 2.0);
        let w = gen.fill(k * k * cin * cout, 0.5);
        let bias = gen.fill(cout, 0.1);
        let x = MapRef::new(h, w_in, cin, &xf);
        let (ho, wo) = ((h + 2 * p - k) / s + 1, (w_in + 2 * p - k) / s + 1);
        let macs = (ho * wo * k * k * cin * cout) as u64;
        let mut out_ref = vec![0.0f32; ho * wo * cout];
        let mut out_opt = vec![0.0f32; ho * wo * cout];
        naive::conv2d_naive(x, &w, &bias, k, s, p, cout, Activation::Relu, &mut out_ref);
        conv2d_into(x, &w, &bias, k, s, p, cout, Activation::Relu, &mut out_opt);
        assert_eq!(out_ref, out_opt, "conv2d f32 parity");
        let naive_r = b.run("conv2d/f32/naive", || {
            naive::conv2d_naive(x, &w, &bias, k, s, p, cout, Activation::Relu, &mut out_ref);
            out_ref[0]
        });
        let opt_r = b.run("conv2d/f32/opt", || {
            conv2d_into(x, &w, &bias, k, s, p, cout, Activation::Relu, &mut out_opt);
            out_opt[0]
        });
        rows.push(KernelRow {
            kernel: "conv2d".into(),
            dtype: "f32".into(),
            shape: shape.clone(),
            naive_us: naive_r.mean_us(),
            opt_us: opt_r.mean_us(),
            macs,
            parity: "bit-identical".into(),
        });

        let q = quantize_case(&xf, &w, &bias, &out_ref);
        let xq = QMapRef::new(h, w_in, cin, &q.xq);
        let mut qout_ref = vec![0i8; ho * wo * cout];
        let mut qout_opt = vec![0i8; ho * wo * cout];
        naive::qconv2d_naive(
            xq, q.x_qp, &q.qp, k, s, p, cout, Activation::Relu, q.out_qp, &mut qout_ref,
        );
        qconv2d_into(
            xq, q.x_qp, &q.qp, k, s, p, cout, Activation::Relu, q.out_qp, &mut qout_opt,
        );
        assert_eq!(qout_ref, qout_opt, "qconv2d int8 parity");
        let naive_r = b.run("conv2d/int8/naive", || {
            naive::qconv2d_naive(
                xq, q.x_qp, &q.qp, k, s, p, cout, Activation::Relu, q.out_qp, &mut qout_ref,
            );
            qout_ref[0]
        });
        let opt_r = b.run("conv2d/int8/opt", || {
            qconv2d_into(
                xq, q.x_qp, &q.qp, k, s, p, cout, Activation::Relu, q.out_qp, &mut qout_opt,
            );
            qout_opt[0]
        });
        rows.push(KernelRow {
            kernel: "qconv2d".into(),
            dtype: "int8".into(),
            shape,
            naive_us: naive_r.mean_us(),
            opt_us: opt_r.mean_us(),
            macs,
            parity: "exact".into(),
        });
    }

    // dwconv2d 32x32x16, k3 s1 p1 — the depthwise half of MobileNet blocks.
    {
        let (h, w_in, c, k, s, p) = (32usize, 32, 16, 3, 1, 1);
        let shape = format!("{h}x{w_in}x{c} k{k} s{s} p{p}");
        let xf = gen.fill(h * w_in * c, 2.0);
        let w = gen.fill(k * k * c, 0.5);
        let bias = gen.fill(c, 0.1);
        let x = MapRef::new(h, w_in, c, &xf);
        let (ho, wo) = ((h + 2 * p - k) / s + 1, (w_in + 2 * p - k) / s + 1);
        let macs = (ho * wo * k * k * c) as u64;
        let mut out_ref = vec![0.0f32; ho * wo * c];
        let mut out_opt = vec![0.0f32; ho * wo * c];
        naive::dwconv2d_naive(x, &w, &bias, k, s, p, Activation::Relu6, &mut out_ref);
        dwconv2d_into(x, &w, &bias, k, s, p, Activation::Relu6, &mut out_opt);
        assert_eq!(out_ref, out_opt, "dwconv2d f32 parity");
        let naive_r = b.run("dwconv2d/f32/naive", || {
            naive::dwconv2d_naive(x, &w, &bias, k, s, p, Activation::Relu6, &mut out_ref);
            out_ref[0]
        });
        let opt_r = b.run("dwconv2d/f32/opt", || {
            dwconv2d_into(x, &w, &bias, k, s, p, Activation::Relu6, &mut out_opt);
            out_opt[0]
        });
        rows.push(KernelRow {
            kernel: "dwconv2d".into(),
            dtype: "f32".into(),
            shape: shape.clone(),
            naive_us: naive_r.mean_us(),
            opt_us: opt_r.mean_us(),
            macs,
            parity: "bit-identical".into(),
        });

        let q = quantize_case(&xf, &w, &bias, &out_ref);
        let xq = QMapRef::new(h, w_in, c, &q.xq);
        let mut qout_ref = vec![0i8; ho * wo * c];
        let mut qout_opt = vec![0i8; ho * wo * c];
        naive::qdwconv2d_naive(
            xq, q.x_qp, &q.qp, k, s, p, Activation::Relu6, q.out_qp, &mut qout_ref,
        );
        qdwconv2d_into(xq, q.x_qp, &q.qp, k, s, p, Activation::Relu6, q.out_qp, &mut qout_opt);
        assert_eq!(qout_ref, qout_opt, "qdwconv2d int8 parity");
        let naive_r = b.run("dwconv2d/int8/naive", || {
            naive::qdwconv2d_naive(
                xq, q.x_qp, &q.qp, k, s, p, Activation::Relu6, q.out_qp, &mut qout_ref,
            );
            qout_ref[0]
        });
        let opt_r = b.run("dwconv2d/int8/opt", || {
            qdwconv2d_into(
                xq, q.x_qp, &q.qp, k, s, p, Activation::Relu6, q.out_qp, &mut qout_opt,
            );
            qout_opt[0]
        });
        rows.push(KernelRow {
            kernel: "qdwconv2d".into(),
            dtype: "int8".into(),
            shape,
            naive_us: naive_r.mean_us(),
            opt_us: opt_r.mean_us(),
            macs,
            parity: "exact".into(),
        });
    }

    // avg/max pool 32x32x16, k2 s2 — pure memory-bound sweeps.
    {
        let (h, w_in, c, k, s) = (32usize, 32, 16, 2, 2);
        let shape = format!("{h}x{w_in}x{c} k{k} s{s}");
        let xf = gen.fill(h * w_in * c, 2.0);
        let x = MapRef::new(h, w_in, c, &xf);
        let (ho, wo) = ((h - k) / s + 1, (w_in - k) / s + 1);
        let mut out_ref = vec![0.0f32; ho * wo * c];
        let mut out_opt = vec![0.0f32; ho * wo * c];
        for (name, is_avg) in [("avg_pool", true), ("max_pool", false)] {
            if is_avg {
                naive::avg_pool2d_naive(x, k, s, &mut out_ref);
                avg_pool2d_into(x, k, s, &mut out_opt);
            } else {
                naive::max_pool2d_naive(x, k, s, &mut out_ref);
                max_pool2d_into(x, k, s, &mut out_opt);
            }
            assert_eq!(out_ref, out_opt, "{name} f32 parity");
            let naive_r = b.run(&format!("{name}/f32/naive"), || {
                if is_avg {
                    naive::avg_pool2d_naive(x, k, s, &mut out_ref);
                } else {
                    naive::max_pool2d_naive(x, k, s, &mut out_ref);
                }
                out_ref[0]
            });
            let opt_r = b.run(&format!("{name}/f32/opt"), || {
                if is_avg {
                    avg_pool2d_into(x, k, s, &mut out_opt);
                } else {
                    max_pool2d_into(x, k, s, &mut out_opt);
                }
                out_opt[0]
            });
            rows.push(KernelRow {
                kernel: name.into(),
                dtype: "f32".into(),
                shape: shape.clone(),
                naive_us: naive_r.mean_us(),
                opt_us: opt_r.mean_us(),
                macs: 0,
                parity: "bit-identical".into(),
            });
        }

        let x_qp = QParams::observe(&xf);
        let mut xq_d = vec![0i8; xf.len()];
        quantize_into(&xf, x_qp, &mut xq_d);
        let xq = QMapRef::new(h, w_in, c, &xq_d);
        let mut qout_ref = vec![0i8; ho * wo * c];
        let mut qout_opt = vec![0i8; ho * wo * c];
        for (name, is_avg) in [("qavg_pool", true), ("qmax_pool", false)] {
            if is_avg {
                naive::qavg_pool2d_naive(xq, x_qp, k, s, x_qp, &mut qout_ref);
                qavg_pool2d_into(xq, x_qp, k, s, x_qp, &mut qout_opt);
            } else {
                naive::qmax_pool2d_naive(xq, x_qp, k, s, x_qp, &mut qout_ref);
                qmax_pool2d_into(xq, x_qp, k, s, x_qp, &mut qout_opt);
            }
            assert_eq!(qout_ref, qout_opt, "{name} int8 parity");
            let naive_r = b.run(&format!("{name}/int8/naive"), || {
                if is_avg {
                    naive::qavg_pool2d_naive(xq, x_qp, k, s, x_qp, &mut qout_ref);
                } else {
                    naive::qmax_pool2d_naive(xq, x_qp, k, s, x_qp, &mut qout_ref);
                }
                qout_ref[0]
            });
            let opt_r = b.run(&format!("{name}/int8/opt"), || {
                if is_avg {
                    qavg_pool2d_into(xq, x_qp, k, s, x_qp, &mut qout_opt);
                } else {
                    qmax_pool2d_into(xq, x_qp, k, s, x_qp, &mut qout_opt);
                }
                qout_opt[0]
            });
            rows.push(KernelRow {
                kernel: name.into(),
                dtype: "int8".into(),
                shape: shape.clone(),
                naive_us: naive_r.mean_us(),
                opt_us: opt_r.mean_us(),
                macs: 0,
                parity: "exact".into(),
            });
        }
    }

    // dense 256 -> 64 — the classifier tail.
    {
        let (din, dout) = (256usize, 64);
        let shape = format!("{din}->{dout}");
        let xf = gen.fill(din, 2.0);
        let w = gen.fill(din * dout, 0.5);
        let bias = gen.fill(dout, 0.1);
        let macs = (din * dout) as u64;
        let mut out_ref = vec![0.0f32; dout];
        let mut out_opt = vec![0.0f32; dout];
        naive::dense_naive(&xf, &w, &bias, dout, &mut out_ref);
        dense_into(&xf, &w, &bias, dout, &mut out_opt);
        assert_eq!(out_ref, out_opt, "dense f32 parity");
        let naive_r = b.run("dense/f32/naive", || {
            naive::dense_naive(&xf, &w, &bias, dout, &mut out_ref);
            out_ref[0]
        });
        let opt_r = b.run("dense/f32/opt", || {
            dense_into(&xf, &w, &bias, dout, &mut out_opt);
            out_opt[0]
        });
        rows.push(KernelRow {
            kernel: "dense".into(),
            dtype: "f32".into(),
            shape: shape.clone(),
            naive_us: naive_r.mean_us(),
            opt_us: opt_r.mean_us(),
            macs,
            parity: "bit-identical".into(),
        });

        let q = quantize_case(&xf, &w, &bias, &out_ref);
        let mut qout_ref = vec![0i8; dout];
        let mut qout_opt = vec![0i8; dout];
        naive::qdense_naive(&q.xq, q.x_qp, &q.qp, dout, q.out_qp, &mut qout_ref);
        qdense_into(&q.xq, q.x_qp, &q.qp, dout, q.out_qp, &mut qout_opt);
        assert_eq!(qout_ref, qout_opt, "qdense int8 parity");
        let naive_r = b.run("dense/int8/naive", || {
            naive::qdense_naive(&q.xq, q.x_qp, &q.qp, dout, q.out_qp, &mut qout_ref);
            qout_ref[0]
        });
        let opt_r = b.run("dense/int8/opt", || {
            qdense_into(&q.xq, q.x_qp, &q.qp, dout, q.out_qp, &mut qout_opt);
            qout_opt[0]
        });
        rows.push(KernelRow {
            kernel: "qdense".into(),
            dtype: "int8".into(),
            shape,
            naive_us: naive_r.mean_us(),
            opt_us: opt_r.mean_us(),
            macs,
            parity: "exact".into(),
        });
    }

    for r in &rows {
        println!(
            "  {:<10} {:<5} {:<24} {:>8.1} -> {:>8.1} us  ({:.2}x, {})",
            r.kernel,
            r.dtype,
            r.shape,
            r.naive_us,
            r.opt_us,
            r.naive_us / r.opt_us.max(1e-9),
            r.parity,
        );
    }

    let json = kernels_snapshot(&rows, smoke);
    // Self-check against the stable schema before committing bytes to
    // disk — a writer/validator drift fails the bench, not CI later.
    if let Err(e) = validate_kernels_snapshot(&json) {
        eprintln!("BENCH_kernels.json failed its own schema check: {e}");
        std::process::exit(1);
    }
    match std::fs::write("BENCH_kernels.json", &json) {
        Ok(()) => println!("wrote BENCH_kernels.json"),
        Err(e) => eprintln!("could not write BENCH_kernels.json: {e}"),
    }
}
