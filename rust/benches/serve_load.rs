//! Serving load harness: concurrent submitter threads drive a live
//! [`MultiModelServer`] across several zoo models and snapshot the
//! serving telemetry — per-model throughput, latency percentiles (exact
//! window + mergeable histograms), queue-wait vs execute splits, queue
//! peaks, and rejection rates. Emits `BENCH_serve.json` at the repo root
//! through the stable `obs::export` schema, the serving-load perf
//! trajectory `msfcnn bench check` and CI gate on.
//!
//! Set `MSFCNN_BENCH_SMOKE=1` for a seconds-scale smoke run (CI): fewer
//! requests, same models, same snapshot schema.

use std::time::Instant;

use msf_cnn::coordinator::{ModelSpec, MultiModelServer};
use msf_cnn::obs::export::{
    serve_snapshot, validate_serve_snapshot, ServeAggregate, ServeConfig, ServeRow,
};
use msf_cnn::obs::TraceLog;
use msf_cnn::ops::ParamGen;
use msf_cnn::optimizer::Planner;
use msf_cnn::zoo;

const MODELS: [&str; 3] = ["quickstart", "kws", "tiny"];

fn main() {
    let smoke = std::env::var("MSFCNN_BENCH_SMOKE").is_ok_and(|v| v != "0");
    let per_thread = if smoke { 50 } else { 400 };
    let threads = 4usize;
    let tag = if smoke { " (smoke)" } else { "" };
    println!("== serve load harness{tag}: {threads} threads x {per_thread} requests ==");

    let mut specs = Vec::new();
    let mut inputs: Vec<(String, Vec<f32>)> = Vec::new();
    for name in MODELS {
        let model = zoo::by_name(name).unwrap();
        let setting = Planner::for_model(model.clone()).setting().unwrap();
        let n = model.shapes[0].elems() as usize;
        inputs.push((name.to_string(), ParamGen::new(9).fill(n, 2.0)));
        specs.push(ModelSpec::engine(name, model, setting).with_queue(64, 8));
    }

    let server = MultiModelServer::start(specs).expect("server start");
    let handle = server.handle();
    let trace = TraceLog::default();
    handle.set_trace_sink(trace.clone());

    // Submitter threads round-robin the models; blocking `infer` keeps
    // each thread at one in-flight request, so contention comes from the
    // thread count, not an unbounded open loop.
    let t0 = Instant::now();
    let workers: Vec<_> = (0..threads)
        .map(|t| {
            let handle = handle.clone();
            let inputs = inputs.clone();
            std::thread::spawn(move || {
                let mut ok = 0usize;
                let mut rejected = 0usize;
                for i in 0..per_thread {
                    let (id, input) = &inputs[(t + i) % inputs.len()];
                    match handle.infer(id, input.clone()) {
                        Ok(_) => ok += 1,
                        Err(_) => rejected += 1,
                    }
                }
                (ok, rejected)
            })
        })
        .collect();
    let mut ok = 0usize;
    let mut rejected = 0usize;
    for w in workers {
        let (o, r) = w.join().expect("submitter thread");
        ok += o;
        rejected += r;
    }
    let wall_s = t0.elapsed().as_secs_f64();
    let offered = threads * per_thread;
    println!(
        "{ok}/{offered} ok ({rejected} rejected) in {wall_s:.2}s ({:.1} req/s)",
        ok as f64 / wall_s.max(1e-9)
    );

    let metrics = handle.metrics();
    let mut rows: Vec<ServeRow> = Vec::new();
    for (id, m) in metrics.per_model() {
        let hist = m.histogram();
        let stats = m.stats();
        rows.push(ServeRow {
            model: id.to_string(),
            completed: m.completed(),
            rejections: m.rejections(),
            shutdown_drops: m.shutdown_drops(),
            throughput_rps: m.throughput_rps().unwrap_or(0.0),
            mean_us: hist.mean_us().unwrap_or(0.0),
            p50_us: stats.map_or_else(|| hist.quantile(0.50).unwrap_or(0.0), |s| s.p50_us),
            p95_us: stats.map_or_else(|| hist.quantile(0.95).unwrap_or(0.0), |s| s.p95_us),
            p99_us: stats.map_or_else(|| hist.quantile(0.99).unwrap_or(0.0), |s| s.p99_us),
            max_us: hist.max_us().unwrap_or(0.0),
            queue_wait_mean_us: m.queue_wait_mean_us().unwrap_or(0.0),
            exec_mean_us: m.exec_mean_us().unwrap_or(0.0),
            queue_peak: m.queue_peak(),
        });
        println!(
            "  {id:<12} {:>6} done  p50 {:>8.0} us  p95 {:>8.0} us  wait {:>6.0} us  exec {:>6.0} us  peak {}",
            m.completed(),
            rows.last().unwrap().p50_us,
            rows.last().unwrap().p95_us,
            rows.last().unwrap().queue_wait_mean_us,
            rows.last().unwrap().exec_mean_us,
            m.queue_peak(),
        );
    }

    // Fleet-wide aggregate from the merged per-model histograms — the
    // mergeability the histogram exists for.
    let merged = metrics.histogram();
    let agg = ServeAggregate {
        completed: metrics.completed(),
        rejections: metrics.rejections(),
        throughput_rps: metrics.completed() as f64 / wall_s.max(1e-9),
        p50_us: merged.quantile(0.50).unwrap_or(0.0),
        p95_us: merged.quantile(0.95).unwrap_or(0.0),
        p99_us: merged.quantile(0.99).unwrap_or(0.0),
    };

    drop(handle);
    server.shutdown();
    println!("trace: {} control-plane event(s)", trace.len());

    let cfg = ServeConfig {
        threads,
        requests: offered,
        smoke,
        models: MODELS.iter().map(|s| s.to_string()).collect(),
    };
    let json = serve_snapshot(&cfg, &rows, &agg);
    // Self-check against the stable schema before committing bytes to
    // disk — a writer/validator drift fails the bench, not CI later.
    if let Err(e) = validate_serve_snapshot(&json) {
        eprintln!("BENCH_serve.json failed its own schema check: {e}");
        std::process::exit(1);
    }
    match std::fs::write("BENCH_serve.json", &json) {
        Ok(()) => println!("wrote BENCH_serve.json"),
        Err(e) => eprintln!("could not write BENCH_serve.json: {e}"),
    }
}
