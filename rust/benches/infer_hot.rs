//! Per-request serving latency: interpreted engine (re-walks the setting,
//! arena-allocates per run) vs the compile-once path (cold compile vs
//! warm allocation-free run). Emits `BENCH_infer.json` at the repo root —
//! the serving-hot-path perf trajectory CI and future PRs track.

use msf_cnn::exec::Engine;
use msf_cnn::memory::Arena;
use msf_cnn::model::ModelChain;
use msf_cnn::ops::{ParamGen, Tensor};
use msf_cnn::optimizer::Planner;
use msf_cnn::util::bench::Bencher;
use msf_cnn::zoo;

fn input_for(m: &ModelChain, seed: u64) -> Tensor {
    let s = m.shapes[0];
    Tensor::from_data(
        s.h as usize,
        s.w as usize,
        s.c as usize,
        ParamGen::new(seed).fill(s.elems() as usize, 2.0),
    )
}

fn main() {
    let b = Bencher::default();
    println!("== infer hot-path benches (interpreted vs compiled) ==");

    let mut rows: Vec<String> = Vec::new();
    for name in ["quickstart", "kws"] {
        let m = zoo::by_name(name).unwrap();
        let engine = Engine::new(m.clone());
        let setting = Planner::for_model(m.clone()).setting().unwrap();
        let x = input_for(&m, 1);

        // Interpreted: per-request re-interpretation + arena allocations.
        let interp = b.run(&format!("interpreted/{name}"), || {
            let mut arena = Arena::unbounded();
            engine.run(&setting, &x, &mut arena).unwrap().macs
        });

        // Cold: what one compile costs (schedule replay + two offset
        // assignments + band geometry).
        let cold = b.run(&format!("compile-cold/{name}"), || {
            engine.compile(&setting).pool_bytes()
        });

        // Warm: the serving hot path — allocation-free inside the pool.
        let compiled = engine.compile(&setting);
        let mut pool = compiled.make_pool();
        let mut out = vec![0.0f32; compiled.output_len()];
        let warm = b.run(&format!("compiled-warm/{name}"), || {
            compiled.run_into(x.as_map(), &mut pool, &mut out);
            out[0]
        });

        rows.push(format!(
            "    {{\"model\": \"{name}\", \"interpreted_us\": {:.1}, \"compile_cold_us\": {:.1}, \"compiled_warm_us\": {:.1}, \"warm_speedup\": {:.3}, \"pool_bytes\": {}, \"watermark_bytes\": {}}}",
            interp.mean_us(),
            cold.mean_us(),
            warm.mean_us(),
            interp.mean_us() / warm.mean_us(),
            compiled.pool_bytes(),
            compiled.measured_peak(),
        ));
    }

    let json = format!(
        "{{\n  \"bench\": \"infer_hot\",\n  \"unit\": \"us-mean\",\n  \"results\": [\n{}\n  ]\n}}\n",
        rows.join(",\n")
    );
    match std::fs::write("BENCH_infer.json", &json) {
        Ok(()) => println!("wrote BENCH_infer.json"),
        Err(e) => eprintln!("could not write BENCH_infer.json: {e}"),
    }
}
