//! Per-request serving latency: interpreted engine (re-walks the setting,
//! arena-allocates per run) vs the compile-once path (cold compile vs
//! warm allocation-free run), with per-step attribution of the warm path
//! from `obs::profile_plan` and — since schema v2 — the int8 compiled
//! twin (`qexec::QCompiledPlan`): warm latency, pool size/watermark, and
//! logit error vs the f32 path. Emits `BENCH_infer.json` at the repo
//! root through the stable `obs::export` schema — the serving-hot-path
//! perf trajectory `msfcnn bench check` and CI gate on.
//!
//! Set `MSFCNN_BENCH_SMOKE=1` for a seconds-scale smoke run (CI): fewer
//! iterations, same models, same snapshot schema.

use msf_cnn::exec::Engine;
use msf_cnn::memory::Arena;
use msf_cnn::model::ModelChain;
use msf_cnn::obs::export::{infer_snapshot, validate_infer_snapshot, InferRow};
use msf_cnn::obs::profile_plan;
use msf_cnn::ops::{ParamGen, Tensor};
use msf_cnn::optimizer::Planner;
use msf_cnn::qexec::{calibrate_default, QCompiledPlan};
use msf_cnn::util::bench::Bencher;
use msf_cnn::zoo;

fn input_for(m: &ModelChain, seed: u64) -> Tensor {
    let s = m.shapes[0];
    Tensor::from_data(
        s.h as usize,
        s.w as usize,
        s.c as usize,
        ParamGen::new(seed).fill(s.elems() as usize, 2.0),
    )
}

fn main() {
    let smoke = std::env::var("MSFCNN_BENCH_SMOKE").is_ok_and(|v| v != "0");
    let b = if smoke { Bencher::quick() } else { Bencher::default() };
    let profile_runs = if smoke { 5 } else { 50 };
    let tag = if smoke { ", smoke" } else { "" };
    println!("== infer hot-path benches (interpreted vs compiled{tag}) ==");

    let mut rows: Vec<InferRow> = Vec::new();
    for name in ["quickstart", "kws"] {
        let m = zoo::by_name(name).unwrap();
        let engine = Engine::new(m.clone());
        let setting = Planner::for_model(m.clone()).setting().unwrap();
        let x = input_for(&m, 1);

        // Interpreted: per-request re-interpretation + arena allocations.
        let interp = b.run(&format!("interpreted/{name}"), || {
            let mut arena = Arena::unbounded();
            engine.run(&setting, &x, &mut arena).unwrap().macs
        });

        // Cold: what one compile costs (schedule replay + two offset
        // assignments + band geometry).
        let cold = b.run(&format!("compile-cold/{name}"), || {
            engine.compile(&setting).pool_bytes()
        });

        // Warm: the serving hot path — allocation-free inside the pool.
        let compiled = engine.compile(&setting);
        let mut pool = compiled.make_pool();
        let mut out = vec![0.0f32; compiled.output_len()];
        let warm = b.run(&format!("compiled-warm/{name}"), || {
            compiled.run_into(x.as_map(), &mut pool, &mut out);
            out[0]
        });

        // Int8 twin: same setting lowered through qexec — warm latency,
        // byte-granular pool footprint, and logit error vs f32.
        let spec = calibrate_default(&m, engine.params());
        let quant = QCompiledPlan::compile(m.clone(), setting.clone(), spec);
        let mut qpool = quant.make_pool();
        let mut qout = vec![0.0f32; quant.output_len()];
        let qwarm = b.run(&format!("quant-warm/{name}"), || {
            quant.run_into(x.as_map(), &mut qpool, &mut qout);
            qout[0]
        });
        let max_abs = qout
            .iter()
            .zip(&out)
            .map(|(a, c)| (a - c).abs() as f64)
            .fold(0.0f64, f64::max);
        println!(
            "  {name}: int8 pool {} B (watermark {} B) vs f32-accounted {} B; max-abs err {max_abs:.4}",
            quant.pool_bytes(),
            quant.measured_peak(),
            compiled.pool_bytes(),
        );

        // Per-step attribution of the warm path: which compiled steps
        // dominate, with p50/p95 per step.
        let profile = profile_plan(&compiled, &x, profile_runs);
        for s in profile.top_k(3) {
            println!(
                "  {name}: {:<18} {:>8.1} us mean  ({:.1}% of in-plan time)",
                s.meta.label,
                s.mean_us,
                s.share * 100.0
            );
        }

        rows.push(InferRow {
            model: name.to_string(),
            interpreted_us: interp.mean_us(),
            compile_cold_us: cold.mean_us(),
            compiled_warm_us: warm.mean_us(),
            pool_bytes: compiled.pool_bytes(),
            watermark_bytes: compiled.measured_peak(),
            quant_warm_us: qwarm.mean_us(),
            quant_pool_bytes: quant.pool_bytes(),
            quant_watermark_bytes: quant.measured_peak(),
            quant_max_abs_err: max_abs,
            profile,
        });
    }

    let json = infer_snapshot(&rows);
    // Self-check against the stable schema before committing bytes to
    // disk — a writer/validator drift fails the bench, not CI later.
    if let Err(e) = validate_infer_snapshot(&json) {
        eprintln!("BENCH_infer.json failed its own schema check: {e}");
        std::process::exit(1);
    }
    match std::fs::write("BENCH_infer.json", &json) {
        Ok(()) => println!("wrote BENCH_infer.json"),
        Err(e) => eprintln!("could not write BENCH_infer.json: {e}"),
    }
}
